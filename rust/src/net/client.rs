//! The thin blocking client: [`Client`] speaks `ffnet/1` to a
//! [`crate::net::server::NetServer`] and exposes the same
//! `offload` / `offload_batch` / `load_result` surface as an
//! in-process [`crate::accel::AccelHandle`] — swap the transport, keep
//! the calling code.
//!
//! Differences from `AccelHandle`, all consequences of the wire:
//!
//! * `load_result` lives on the client (results come back down the
//!   same socket), where in-process it lives on the pool.
//! * [`Client::finish`] takes `&mut self`, not `self`: after sending
//!   `Eos` the caller keeps draining results until `load_result`
//!   returns `Ok(None)` (the server's answering `Eos`).
//! * Every call can fail with [`AccelError::Io`] /
//!   [`AccelError::Protocol`] / [`AccelError::Disconnected`].
//!
//! The client **self-throttles** to the server's advertised admission
//! window: `flush` chunks runs to at most `window` items per frame and
//! blocks pumping results once `in_flight + chunk` would overflow it —
//! so a cooperating client is never shed. Buffers recycle on both
//! directions (send-side `Vec<I>` stack, result-side `Vec<O>` stack),
//! keeping the steady state allocation-free end to end.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::accel::AccelError;
use crate::net::frame::{self, Frame, FrameDecoder, Kind, Wire, WELCOME_LEN};

/// Map transport errors: orderly peer-gone kinds become
/// [`AccelError::Disconnected`] (matching what an in-process caller
/// sees when the accelerator dies), anything else keeps its kind.
fn io_err(e: std::io::Error) -> AccelError {
    use std::io::ErrorKind as K;
    match e.kind() {
        K::BrokenPipe | K::ConnectionReset | K::ConnectionAborted | K::UnexpectedEof => {
            AccelError::Disconnected
        }
        kind => AccelError::Io(kind),
    }
}

/// Blocking `ffnet/1` client (module docs).
#[derive(Debug)]
pub struct Client<I: Wire, O: Wire> {
    stream: TcpStream,
    dec: FrameDecoder,
    /// Server's admission window (items), learned in the welcome.
    window: u32,
    seq: u32,
    /// Auto-coalescing threshold, as on `AccelHandle` (1 = send each
    /// task as its own frame).
    batch: usize,
    buf: Vec<I>,
    spare: Vec<Vec<I>>,
    ospare: Vec<Vec<O>>,
    pending: VecDeque<O>,
    wbuf: Vec<u8>,
    rbuf: Vec<u8>,
    /// Items sent (admitted or not — sheds are subtracted via `shed`).
    sent: u64,
    received: u64,
    shed: u64,
    shed_frames: u64,
    finished: bool,
    eos_seen: bool,
}

impl<I: Wire, O: Wire> Client<I, O> {
    /// Connect and handshake. The hello pins the task/result encodings
    /// (`I::SIZE`/`O::SIZE`); a server running a different workload
    /// rejects by hanging up, surfacing [`AccelError::Disconnected`].
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self, AccelError> {
        let mut stream = TcpStream::connect(addr).map_err(io_err)?;
        stream.set_nodelay(true).map_err(io_err)?;
        stream
            .write_all(&frame::encode_hello(I::SIZE as u16, O::SIZE as u16))
            .map_err(io_err)?;
        let mut welcome = [0u8; WELCOME_LEN];
        stream.read_exact(&mut welcome).map_err(io_err)?;
        let (window, max_frame) = frame::decode_welcome(&welcome).map_err(AccelError::Protocol)?;
        Ok(Client {
            stream,
            dec: FrameDecoder::new(max_frame),
            window: window.max(1),
            seq: 0,
            batch: 1,
            buf: Vec::new(),
            spare: Vec::new(),
            ospare: Vec::new(),
            pending: VecDeque::new(),
            wbuf: Vec::new(),
            rbuf: vec![0u8; 16 * 1024],
            sent: 0,
            received: 0,
            shed: 0,
            shed_frames: 0,
            finished: false,
            eos_seen: false,
        })
    }

    /// The server's advertised per-connection in-flight window.
    pub fn window(&self) -> u32 {
        self.window
    }

    /// Items currently in flight (sent − delivered − shed).
    pub fn in_flight(&self) -> u64 {
        self.sent - (self.received + self.pending.len() as u64) - self.shed
    }

    /// Items the server shed (admission control). Zero for clients that
    /// only offload through this type — the self-throttle keeps the
    /// window; nonzero only after out-of-band traffic on the same conn.
    pub fn shed_items(&self) -> u64 {
        self.shed
    }

    /// Shed frames observed.
    pub fn shed_frames(&self) -> u64 {
        self.shed_frames
    }

    /// Tasks offloaded so far (mirrors `AccelHandle::offloaded`).
    pub fn offloaded(&self) -> u64 {
        self.sent + self.buf.len() as u64
    }

    /// Set the auto-coalescing threshold (tasks per frame), as on
    /// [`crate::accel::AccelHandle::set_batch`].
    pub fn set_batch(&mut self, batch: usize) -> Result<(), AccelError> {
        let want = batch.max(1);
        if want < self.batch && self.buf.len() >= want {
            self.flush()?;
        }
        self.batch = want;
        Ok(())
    }

    /// Current coalescing threshold.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Take an empty recycled task buffer (pair with
    /// [`Client::offload_batch`] for the allocation-free cycle).
    #[must_use]
    // ffaudit: allow(recycle) — this *is* the lender: buffers come back
    // through the local `spare` stack pushed by the result pump, so the
    // return path is structural, not a recycle() call.
    pub fn take_batch_buf(&mut self) -> Vec<I> {
        self.spare.pop().unwrap_or_default()
    }

    /// Offload one task; ships a frame when the coalescing threshold
    /// fills. Blocks only when the admission window is full (pumping
    /// results while it waits).
    pub fn offload(&mut self, task: I) -> Result<(), AccelError> {
        if self.finished {
            return Err(AccelError::Closed);
        }
        self.buf.push(task);
        if self.buf.len() >= self.batch {
            self.flush()?;
        }
        Ok(())
    }

    /// Offload a pre-built batch. The frame ships immediately (after
    /// any coalescing remainder) and `tasks`' buffer is recycled.
    pub fn offload_batch(&mut self, tasks: Vec<I>) -> Result<(), AccelError> {
        if self.finished {
            return Err(AccelError::Closed);
        }
        if tasks.is_empty() {
            self.spare.push(tasks);
            return Ok(());
        }
        self.flush()?;
        self.send_run(tasks)
    }

    /// Ship any coalesced tasks now.
    pub fn flush(&mut self) -> Result<(), AccelError> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let run = std::mem::replace(&mut self.buf, self.spare.pop().unwrap_or_default());
        self.send_run(run)
    }

    /// Send `run` as one or more Batch frames of at most `window` items
    /// each, pumping results whenever the next chunk would overflow the
    /// admission window.
    fn send_run(&mut self, run: Vec<I>) -> Result<(), AccelError> {
        for at in (0..run.len()).step_by(self.window as usize) {
            let chunk = &run[at..run.len().min(at + self.window as usize)];
            while self.in_flight() + chunk.len() as u64 > self.window as u64 {
                self.pump()?;
            }
            self.wbuf.clear();
            frame::encode_items(Kind::Batch, self.seq, chunk, &mut self.wbuf);
            self.stream.write_all(&self.wbuf).map_err(io_err)?;
            self.seq = self.seq.wrapping_add(1);
            self.sent += chunk.len() as u64;
        }
        let mut buf = run;
        buf.clear();
        self.spare.push(buf);
        Ok(())
    }

    /// Send `Eos` (no more offloads). Unlike
    /// [`crate::accel::AccelHandle::finish`] this does **not** consume
    /// the client: keep calling [`Client::load_result`] until it
    /// returns `Ok(None)` — the server answers `Eos` once the last
    /// in-flight result is delivered.
    pub fn finish(&mut self) -> Result<(), AccelError> {
        if self.finished {
            return Ok(());
        }
        self.flush()?;
        self.finished = true;
        self.stream
            .write_all(&frame::encode_ctl(Kind::Eos, 0, 0))
            .map_err(io_err)
    }

    /// Pop the next result, blocking on the socket when none is
    /// buffered. `Ok(None)` only after [`Client::finish`]'s handshake
    /// completes (server `Eos`).
    pub fn load_result(&mut self) -> Result<Option<O>, AccelError> {
        loop {
            if let Some(v) = self.pending.pop_front() {
                self.received += 1;
                return Ok(Some(v));
            }
            if self.eos_seen {
                return Ok(None);
            }
            self.pump()?;
        }
    }

    /// Pop a buffered result without touching the socket.
    #[must_use]
    pub fn load_result_nb(&mut self) -> Option<O> {
        let v = self.pending.pop_front();
        if v.is_some() {
            self.received += 1;
        }
        v
    }

    /// One blocking socket read + frame drain.
    fn pump(&mut self) -> Result<(), AccelError> {
        let n = self.stream.read(&mut self.rbuf).map_err(io_err)?;
        if n == 0 {
            // Peer hung up; only orderly after the Eos handshake.
            return if self.eos_seen {
                Ok(())
            } else {
                Err(AccelError::Disconnected)
            };
        }
        self.dec.extend(&self.rbuf[..n]);
        // Split borrows: the decoder and the recycle stack are distinct
        // fields, but a `self.`-qualified closure would alias `self`.
        let (dec, ospare) = (&mut self.dec, &mut self.ospare);
        loop {
            let next = dec
                .next::<O, O>(|| ospare.pop().unwrap_or_default(), |v| v)
                .map_err(AccelError::Protocol)?;
            match next {
                None => return Ok(()),
                Some(Frame::Items {
                    kind: Kind::Result,
                    items,
                    ..
                }) => {
                    let mut buf = items;
                    self.pending.extend(buf.drain(..));
                    ospare.push(buf);
                }
                Some(Frame::Shed { count, .. }) => {
                    self.shed += count as u64;
                    self.shed_frames += 1;
                }
                Some(Frame::Eos) => {
                    self.eos_seen = true;
                }
                // Batch frames flow client→server only.
                Some(Frame::Items { kind, .. }) => {
                    return Err(AccelError::Protocol(frame::ProtocolError::Unexpected(kind as u8)));
                }
            }
        }
    }
}
