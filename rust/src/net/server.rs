//! `ffserve` — [`crate::accel::AccelPool`] behind a TCP wire protocol.
//!
//! Each accepted connection gets a **reader thread** that is an
//! ordinary cloned [`AccelHandle`] client of the shared pool: it
//! decodes `ffnet/1` batch frames straight into recycled batch buffers
//! ([`crate::net::frame::FrameDecoder::next`] with
//! [`AccelHandle::take_batch_buf`] as the lender), tags every task with
//! its connection id, and offloads. A **writer thread** per connection
//! drains that connection's tagged results (routed by the pool-wide
//! drain thread) back down the socket, coalescing whatever is ready
//! into one `Result` frame per wakeup. Results cross the pool in
//! completion order and are returned to each client in that order —
//! the same contract as in-process [`crate::accel::AccelPool`].
//!
//! ```text
//!  conn₀ ─TCP─▶ reader₀ ─AccelHandle─┐                ┌─▶ writer₀ ─TCP─▶ conn₀
//!  conn₁ ─TCP─▶ reader₁ ─AccelHandle─┼─▶ AccelPool ──▶│ drain (routes by
//!      ⋮                             │   (shards)     │  Tagged::conn)
//!  connₙ ─TCP─▶ readerₙ ─AccelHandle─┘                └─▶ writerₙ ─TCP─▶ connₙ
//! ```
//!
//! ## Admission control
//!
//! Every connection carries a bounded in-flight window (handshake-
//! advertised, [`ServerConfig::window`] items): the reader admits a
//! batch only while `in_flight + batch ≤ window`, otherwise it **sheds
//! the whole frame** — items are dropped before touching the pool and
//! the client is told with a `Shed` frame echoing the batch's sequence
//! number. A cooperating client ([`crate::net::Client`]) self-throttles
//! below the window and never sheds; a firehosing one degrades itself,
//! not its neighbours.
//!
//! ## Hostile-client containment (the PR 5 machinery)
//!
//! * **Mid-stream disconnect** — the reader **cancels** the
//!   connection's queued-but-unstarted work first: every admitted frame
//!   is a tracked job ([`crate::accel::JobToken`]), so frames the
//!   arbiter has not yet claimed are revoked (cancel ≡ never-submitted
//!   — the pool never burns shard time for a client that is gone;
//!   counted in [`NetStats::cancelled_jobs`]). Then it drops its handle
//!   (closing its lane like any in-process client), and the
//!   pool keeps serving everyone else. Should a lane nevertheless be
//!   leaked, the drain's blocking [`AccelPool::load_result`] fires
//!   `ForceClose` after [`crate::accel::PoolConfig::disconnect_grace`]
//!   and [`AccelPool::wait_checked`] reports
//!   [`AccelError::Disconnected`] — `shutdown` never wedges.
//! * **Slowloris** — a connection holding a *partial frame* that makes
//!   no byte progress for [`ServerConfig::stall_timeout`] is killed
//!   (an idle connection with no pending bytes is never touched).
//! * **Idle service** — the pool is forced to at least
//!   [`WaitMode::Adaptive`], so a server with no traffic parks its
//!   shard threads ([`crate::util::ParkGauge`] observable) instead of
//!   spinning on its CPUs.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::accel::{AccelError, AccelHandle, AccelPool, JobToken, PoolConfig, Priority};
use crate::net::frame::{self, Frame, FrameDecoder, Kind, Wire, DEFAULT_MAX_FRAME, HELLO_LEN};
use crate::node::node_fn;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::trace::TraceReport;
use crate::util::{Backoff, WaitMode};

/// A task or result labelled with the connection it belongs to — what
/// actually flows through the pool, so the drain can route each result
/// back to its socket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tagged<T> {
    /// Server-assigned connection id.
    pub conn: u32,
    pub val: T,
}

/// Server tuning knobs. `Default` serves from a
/// [`PoolConfig::default`] pool with a 1024-item window.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// The shared pool the connections offload into. `wait` is raised
    /// to at least [`WaitMode::Adaptive`] at bind time: the shed-lane
    /// recovery (`disconnect_grace`) needs a parking-capable drain, and
    /// an idle *service* must release its CPUs.
    pub pool: PoolConfig,
    /// Per-connection in-flight item window (admission control);
    /// advertised in the welcome. Also the largest admissible batch
    /// frame — a single frame with more items than the window is always
    /// shed, so clients chunk to `window`.
    pub window: u32,
    /// Frame payload cap enforced by the decoder (and advertised to
    /// clients).
    pub max_frame: u32,
    /// Poll period of the (nonblocking) accept loop.
    pub accept_tick: Duration,
    /// Socket read timeout — the granularity at which readers notice
    /// shutdown and stalls.
    pub read_tick: Duration,
    /// Kill a connection whose partially-received frame makes no byte
    /// progress for this long (slowloris containment). Also the
    /// handshake deadline.
    pub stall_timeout: Duration,
    /// Priority class stamped on every connection's offloads (bites
    /// under an elastic pool, [`PoolConfig::elastic`]): run a bulk
    /// ingest service at [`Priority::Low`] next to an interactive pool
    /// without a wire change. Per-connection negotiation would need a
    /// `ffnet/2` hello field — until then the whole server shares one
    /// class.
    pub priority: Priority,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            pool: PoolConfig::default(),
            window: 1024,
            max_frame: DEFAULT_MAX_FRAME,
            accept_tick: Duration::from_millis(20),
            read_tick: Duration::from_millis(50),
            stall_timeout: Duration::from_secs(2),
            priority: Priority::Normal,
        }
    }
}

impl ServerConfig {
    pub fn pool(mut self, pool: PoolConfig) -> Self {
        self.pool = pool;
        self
    }

    pub fn window(mut self, items: u32) -> Self {
        self.window = items;
        self
    }

    pub fn max_frame(mut self, bytes: u32) -> Self {
        self.max_frame = bytes;
        self
    }

    pub fn stall_timeout(mut self, d: Duration) -> Self {
        self.stall_timeout = d;
        self
    }

    pub fn read_tick(mut self, d: Duration) -> Self {
        self.read_tick = d;
        self
    }

    /// Priority class for every connection's offloads (see
    /// [`field@ServerConfig::priority`]).
    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }
}

/// Lifetime counters, kept on relaxed atomics (observability only).
#[derive(Debug, Default)]
struct Counters {
    accepted: AtomicU64,
    rejected: AtomicU64,
    stalled: AtomicU64,
    disconnected: AtomicU64,
    shed_frames: AtomicU64,
    shed_items: AtomicU64,
    admitted_items: AtomicU64,
    cancelled_jobs: AtomicU64,
    cancelled_items: AtomicU64,
}

/// Point-in-time snapshot of the server's connection/admission
/// counters ([`NetServer::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
#[non_exhaustive]
pub struct NetStats {
    /// Connections accepted (post-handshake).
    pub accepted: u64,
    /// Connections dropped at handshake (bad magic, wrong item sizes,
    /// handshake timeout).
    pub rejected: u64,
    /// Connections killed by the slowloris stall timeout.
    pub stalled: u64,
    /// Connections that vanished mid-stream (EOF/reset before `Eos`).
    pub disconnected: u64,
    /// Whole batch frames shed by admission control.
    pub shed_frames: u64,
    /// Items inside those shed frames.
    pub shed_items: u64,
    /// Items admitted into the pool.
    pub admitted_items: u64,
    /// Admitted-but-unstarted jobs revoked when their connection died
    /// (the cancel won, so the pool never dispatched them — cancel ≡
    /// never-submitted).
    pub cancelled_jobs: u64,
    /// Items inside those cancelled jobs.
    pub cancelled_items: u64,
}

impl Counters {
    fn snapshot(&self) -> NetStats {
        NetStats {
            // ordering: stat — lifetime observability counters; no
            // inter-thread edge rides on them.
            accepted: self.accepted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            stalled: self.stalled.load(Ordering::Relaxed),
            disconnected: self.disconnected.load(Ordering::Relaxed),
            shed_frames: self.shed_frames.load(Ordering::Relaxed),
            shed_items: self.shed_items.load(Ordering::Relaxed),
            admitted_items: self.admitted_items.load(Ordering::Relaxed),
            cancelled_jobs: self.cancelled_jobs.load(Ordering::Relaxed),
            cancelled_items: self.cancelled_items.load(Ordering::Relaxed),
        }
    }
}

/// What [`NetServer::shutdown`] returns: the pool's trace, the pool's
/// terminal health, and the connection counters.
#[derive(Debug)]
pub struct ServerReport {
    /// Per-stage trace rows from [`AccelPool::wait_checked`].
    pub trace: TraceReport,
    /// `Some` if the pool terminated unhealthily (e.g.
    /// [`AccelError::Disconnected`] after a force-closed leaked lane).
    pub error: Option<AccelError>,
    pub stats: NetStats,
}

/// Messages into a connection's writer thread. `Result` comes from the
/// pool-wide drain; the rest from the connection's own reader.
enum WriterMsg<O> {
    Result(O),
    Shed { seq: u32, count: u32 },
    ClientEos,
    ReaderGone,
}

/// What a reader sends the drain to register its connection's writer:
/// the connection id and the writer's inbox.
type WriterReg<O> = (u32, mpsc::Sender<WriterMsg<Tagged<O>>>);

/// A running accelerator service (see the module docs). Obtained from
/// [`serve`]; untyped — the workload generics live only in the threads.
///
/// Dropping a `NetServer` without calling [`NetServer::shutdown`]
/// performs the same orderly teardown, discarding the report.
#[derive(Debug)]
pub struct NetServer {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    /// One clone per live-or-dead connection, so shutdown can unblock
    /// every reader with `Shutdown::Both`. Grows monotonically — a
    /// long-lived server with millions of short connections would want
    /// pruning; the entries are just fds + a sockaddr each.
    socks: Arc<Mutex<Vec<TcpStream>>>,
    accept_join: Option<thread::JoinHandle<()>>,
    drain_join: Option<thread::JoinHandle<(TraceReport, Option<AccelError>)>>,
    counters: Arc<Counters>,
}

impl NetServer {
    /// The bound address — useful with port 0 (tests, loopback benches).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Snapshot the connection/admission counters.
    pub fn stats(&self) -> NetStats {
        self.counters.snapshot()
    }

    /// Orderly teardown: stop accepting, unblock and join every
    /// connection, send the pool its EOS, and wait for it. Total time
    /// is bounded by the socket ticks plus the pool's
    /// `disconnect_grace` — a wedged client cannot wedge shutdown.
    pub fn shutdown(mut self) -> ServerReport {
        let (trace, error) = self.teardown().expect("first shutdown");
        ServerReport {
            trace,
            error,
            stats: self.counters.snapshot(),
        }
    }

    fn teardown(&mut self) -> Option<(TraceReport, Option<AccelError>)> {
        self.drain_join.as_ref()?;
        self.shutdown.store(true, Ordering::SeqCst);
        for s in self.socks.lock().expect("socks lock").iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        let out = self
            .drain_join
            .take()
            .expect("checked above")
            .join()
            .unwrap_or((TraceReport::default(), Some(AccelError::Disconnected)));
        Some(out)
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        let _ = self.teardown();
    }
}

/// Bind `addr` and serve the workload built by `factory` (one worker
/// closure per pool `(shard, worker)` slot, exactly like
/// [`AccelPool::run`]). Every worker must emit **exactly one result per
/// task** — the per-connection in-flight accounting (and therefore
/// admission control and `Eos` completion) depends on the 1:1 contract.
///
/// `I`/`O` are the wire task/result types; their encoded sizes are
/// checked against each client's hello.
pub fn serve<I, O, F, G>(
    addr: impl ToSocketAddrs,
    cfg: ServerConfig,
    mut factory: F,
) -> std::io::Result<NetServer>
where
    I: Wire,
    O: Wire,
    F: FnMut(usize, usize) -> G,
    G: FnMut(I) -> O + Send + 'static,
{
    assert!(
        I::SIZE <= u16::MAX as usize && O::SIZE <= u16::MAX as usize,
        "ffnet/1 item encodings are u16-sized"
    );
    let mut pool_cfg = cfg.pool.clone();
    // The service floor: disconnect_grace recovery needs a non-Spin
    // drain, and an idle service must park, not spin.
    pool_cfg.wait = pool_cfg.wait.max(WaitMode::Adaptive);
    let (pool, root) = AccelPool::run(pool_cfg, move |s, w| {
        let mut f = factory(s, w);
        node_fn(move |t: Tagged<I>| Tagged {
            conn: t.conn,
            val: f(t.val),
        })
    });

    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let local_addr = listener.local_addr()?;

    let shutdown = Arc::new(AtomicBool::new(false));
    let socks: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
    let counters = Arc::new(Counters::default());
    let (reg_tx, reg_rx) = mpsc::channel::<WriterReg<O>>();

    let accept_join = {
        let shutdown = Arc::clone(&shutdown);
        let socks = Arc::clone(&socks);
        let counters = Arc::clone(&counters);
        let cfg = cfg.clone();
        thread::Builder::new()
            .name("ffnet-accept".into())
            .spawn(move || {
                accept_loop::<I, O>(listener, cfg, root, shutdown, socks, counters, reg_tx)
            })
            .expect("spawn accept thread")
    };

    let drain_join = {
        let shutdown = Arc::clone(&shutdown);
        thread::Builder::new()
            .name("ffnet-drain".into())
            .spawn(move || drain_loop(pool, reg_rx, shutdown))
            .expect("spawn drain thread")
    };

    Ok(NetServer {
        local_addr,
        shutdown,
        socks,
        accept_join: Some(accept_join),
        drain_join: Some(drain_join),
        counters,
    })
}

/// Accept loop: poll the nonblocking listener, spawn one reader per
/// connection, and on shutdown join them all (readers join their
/// writers), then drop the root handle so the pool's client count can
/// reach zero.
#[allow(clippy::too_many_arguments)]
fn accept_loop<I: Wire, O: Wire>(
    listener: TcpListener,
    cfg: ServerConfig,
    root: AccelHandle<Tagged<I>>,
    shutdown: Arc<AtomicBool>,
    socks: Arc<Mutex<Vec<TcpStream>>>,
    counters: Arc<Counters>,
    reg_tx: mpsc::Sender<WriterReg<O>>,
) {
    let mut readers = Vec::new();
    let mut next_conn: u32 = 0;
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let conn = next_conn;
                next_conn += 1;
                if let Ok(clone) = stream.try_clone() {
                    socks.lock().expect("socks lock").push(clone);
                } else {
                    continue;
                }
                let handle = root.clone();
                let shutdown = Arc::clone(&shutdown);
                let counters = Arc::clone(&counters);
                let reg_tx = reg_tx.clone();
                let cfg = cfg.clone();
                let j = thread::Builder::new()
                    .name(format!("ffnet-conn-{conn}"))
                    .spawn(move || {
                        reader_thread::<I, O>(stream, conn, cfg, handle, shutdown, counters, reg_tx)
                    })
                    .expect("spawn reader thread");
                readers.push(j);
            }
            // WouldBlock (no pending connection) or a transient accept
            // error — tick and re-check the shutdown flag.
            Err(_) => thread::sleep(cfg.accept_tick),
        }
    }
    for j in readers {
        let _ = j.join();
    }
    drop(root);
}

/// Read exactly `buf.len()` handshake bytes, tolerating the read
/// timeout, until `deadline` or shutdown. `Ok(true)` = filled.
fn read_exact_by(
    stream: &mut TcpStream,
    buf: &mut [u8],
    deadline: Instant,
    shutdown: &AtomicBool,
) -> std::io::Result<bool> {
    let mut at = 0;
    while at < buf.len() {
        if shutdown.load(Ordering::SeqCst) || Instant::now() >= deadline {
            return Ok(false);
        }
        match stream.read(&mut buf[at..]) {
            Ok(0) => return Ok(false),
            Ok(n) => at += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Per-connection reader: handshake, then decode batch frames into
/// recycled buffers and offload through this connection's own
/// [`AccelHandle`] lane, shedding past the admission window.
fn reader_thread<I: Wire, O: Wire>(
    mut stream: TcpStream,
    conn: u32,
    cfg: ServerConfig,
    mut handle: AccelHandle<Tagged<I>>,
    shutdown: Arc<AtomicBool>,
    counters: Arc<Counters>,
    reg_tx: mpsc::Sender<WriterReg<O>>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(cfg.read_tick));

    // Handshake: each reader does its own, so a client stalling its
    // hello ties up only this thread, never the accept loop.
    let mut hello = [0u8; HELLO_LEN];
    let deadline = Instant::now() + cfg.stall_timeout;
    match read_exact_by(&mut stream, &mut hello, deadline, &shutdown) {
        Ok(true) => {}
        _ => {
            // ordering: stat — observability counter.
            counters.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
    }
    let want = (I::SIZE as u16, O::SIZE as u16);
    match frame::decode_hello(&hello) {
        Ok(got) if got == want => {}
        _ => {
            // ordering: stat — observability counter.
            counters.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
    }
    if stream
        .write_all(&frame::encode_welcome(cfg.window, cfg.max_frame))
        .is_err()
    {
        // ordering: stat — observability counter.
        counters.rejected.fetch_add(1, Ordering::Relaxed);
        return;
    }
    // ordering: stat — observability counter.
    counters.accepted.fetch_add(1, Ordering::Relaxed);

    // Register with the drain BEFORE the first offload, so every result
    // finds its writer. The writer gets its own socket clone.
    let (wtx, wrx) = mpsc::channel::<WriterMsg<Tagged<O>>>();
    let _ = reg_tx.send((conn, wtx.clone()));
    let in_flight = Arc::new(AtomicU64::new(0));
    let writer_join = match stream.try_clone() {
        Ok(wstream) => {
            let in_flight = Arc::clone(&in_flight);
            thread::Builder::new()
                .name(format!("ffnet-write-{conn}"))
                .spawn(move || writer_thread::<O>(wstream, wrx, in_flight))
                .expect("spawn writer thread")
        }
        Err(_) => {
            // ordering: stat — observability counter.
            counters.disconnected.fetch_add(1, Ordering::Relaxed);
            return;
        }
    };

    // Every offload from this connection carries the server's priority
    // class (bites under an elastic pool; free otherwise).
    handle.set_priority(cfg.priority);
    let window = cfg.window as u64;
    let mut dec = FrameDecoder::new(cfg.max_frame);
    // Local recycle stack: shed frames give their buffers straight
    // back; admitted ones come back through the handle's BatchPool lane
    // (`take_batch_buf`). Steady state allocates nothing per frame.
    let mut spare: Vec<Vec<Tagged<I>>> = Vec::new();
    // One JobToken per admitted frame, so a dead connection's
    // queued-but-unstarted work can be revoked instead of drained.
    // Settled tokens (dispatched already) are pruned as we go.
    let mut tokens: Vec<(JobToken, u64)> = Vec::new();
    let mut rbuf = [0u8; 16 * 1024];
    let mut last_progress = Instant::now();
    let mut clean = false;

    'conn: while !shutdown.load(Ordering::SeqCst) {
        // Drain every complete frame before reading more bytes.
        loop {
            let next = dec.next::<I, Tagged<I>>(
                // ffaudit: allow(recycle) — shed/cleared buffers return via
                // the local `spare` stack pushed below, not a recycle() call.
                || spare.pop().unwrap_or_else(|| handle.take_batch_buf()),
                |val| Tagged { conn, val },
            );
            match next {
                Ok(None) => break,
                Ok(Some(Frame::Items {
                    kind: Kind::Batch,
                    seq,
                    items,
                })) => {
                    let n = items.len() as u64;
                    // ordering: net — admission check; pairs with the
                    // writer's fetch_sub(AcqRel) release of window credit.
                    if in_flight.load(Ordering::Acquire) + n > window {
                        // ordering: stat — observability counters.
                        counters.shed_frames.fetch_add(1, Ordering::Relaxed);
                        counters.shed_items.fetch_add(n, Ordering::Relaxed);
                        let mut buf = items;
                        buf.clear();
                        spare.push(buf);
                        if wtx
                            .send(WriterMsg::Shed {
                                seq,
                                count: n as u32,
                            })
                            .is_err()
                        {
                            break 'conn;
                        }
                    } else {
                        // ordering: net — take window credit before the
                        // offload publishes the items.
                        in_flight.fetch_add(n, Ordering::AcqRel);
                        // ordering: stat — observability counter.
                        counters.admitted_items.fetch_add(n, Ordering::Relaxed);
                        tokens.retain(|(t, _)| !t.is_settled());
                        match handle.offload_batch_job(items) {
                            Ok(token) => tokens.push((token, n)),
                            Err(_) => {
                                // Pool gone (poisoned); nothing to serve.
                                break 'conn;
                            }
                        }
                    }
                }
                Ok(Some(Frame::Eos)) => {
                    clean = true;
                    let _ = wtx.send(WriterMsg::ClientEos);
                    break 'conn;
                }
                // Result/Shed flow server→client only; treat them (and
                // any codec error) as a protocol violation and hang up.
                Ok(Some(Frame::Items { .. })) | Ok(Some(Frame::Shed { .. })) | Err(_) => {
                    break 'conn;
                }
            }
        }

        match stream.read(&mut rbuf) {
            Ok(0) => {
                // ordering: stat — observability counter.
                counters.disconnected.fetch_add(1, Ordering::Relaxed);
                break;
            }
            Ok(n) => {
                dec.extend(&rbuf[..n]);
                last_progress = Instant::now();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Slowloris: a *partial frame* making no progress. An
                // idle connection (no pending bytes) is left alone.
                if dec.pending() > 0 && last_progress.elapsed() >= cfg.stall_timeout {
                    // ordering: stat — observability counter.
                    counters.stalled.fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
            Err(_) => {
                // ordering: stat — observability counter.
                counters.disconnected.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }

    if !clean {
        // The connection died mid-stream: revoke whatever the arbiter
        // has not claimed yet. Each cancel either wins (the frame never
        // reaches a shard — cancel ≡ never-submitted) or loses (already
        // dispatched; its results are discarded by the drain once the
        // writer is gone). Exactly one outcome per job.
        let (mut cj, mut ci) = (0u64, 0u64);
        for (t, n) in tokens.drain(..) {
            if t.cancel() {
                cj += 1;
                ci += n;
            }
        }
        if cj > 0 {
            // ordering: stat — observability counters.
            counters.cancelled_jobs.fetch_add(cj, Ordering::Relaxed);
            counters.cancelled_items.fetch_add(ci, Ordering::Relaxed);
        }
        let _ = wtx.send(WriterMsg::ReaderGone);
    }
    // Drop our sender before joining: once the drain also lets go of
    // its clone, the writer's `recv` errors out — so even a writer
    // waiting on results that will never come (poisoned pool) unblocks
    // and this join stays bounded.
    drop(wtx);
    // Close this connection's lane; already-offloaded tasks still
    // complete (their results route to the writer, or are discarded by
    // the drain once the writer is gone).
    drop(handle);
    // Join BEFORE shutting the socket: writer and reader share the
    // underlying socket (`try_clone`), so an early shutdown would cut
    // off the writer's final Shed/Eos frames mid-handshake.
    let _ = writer_join.join();
    let _ = stream.shutdown(Shutdown::Both);
}

/// Per-connection writer: coalesce whatever results are ready into one
/// `Result` frame per wakeup, answer sheds, and close the stream with a
/// wire `Eos` once the client's `Eos` arrived and the last in-flight
/// result went out.
fn writer_thread<O: Wire>(
    mut stream: TcpStream,
    wrx: mpsc::Receiver<WriterMsg<Tagged<O>>>,
    in_flight: Arc<AtomicU64>,
) {
    let mut eos = false;
    let mut results: Vec<O> = Vec::new();
    let mut sheds: Vec<(u32, u32)> = Vec::new();
    let mut gone = false;
    let mut scratch: Vec<u8> = Vec::new();
    'outer: loop {
        match wrx.recv() {
            Ok(m) => sort_msg(m, &mut results, &mut sheds, &mut eos, &mut gone),
            Err(_) => break, // all senders gone (teardown)
        }
        // Greedily coalesce everything already queued.
        while let Ok(m) = wrx.try_recv() {
            sort_msg(m, &mut results, &mut sheds, &mut eos, &mut gone);
        }
        if gone {
            break;
        }
        if !results.is_empty() {
            scratch.clear();
            frame::encode_items(Kind::Result, 0, &results, &mut scratch);
            if stream.write_all(&scratch).is_err() {
                break;
            }
            // ordering: net — return window credit only after the results
            // hit the socket; pairs with the reader's admission Acquire.
            in_flight.fetch_sub(results.len() as u64, Ordering::AcqRel);
            results.clear();
        }
        for (seq, count) in sheds.drain(..) {
            if stream
                .write_all(&frame::encode_ctl(Kind::Shed, seq, count))
                .is_err()
            {
                break 'outer;
            }
        }
        // ordering: net — the wire Eos gate: every admitted item's
        // fetch_sub must be visible before we close the stream.
        if eos && in_flight.load(Ordering::Acquire) == 0 {
            let _ = stream.write_all(&frame::encode_ctl(Kind::Eos, 0, 0));
            break;
        }
    }
}

fn sort_msg<T>(
    m: WriterMsg<Tagged<T>>,
    results: &mut Vec<T>,
    sheds: &mut Vec<(u32, u32)>,
    eos: &mut bool,
    gone: &mut bool,
) {
    match m {
        WriterMsg::Result(t) => results.push(t.val),
        WriterMsg::Shed { seq, count } => sheds.push((seq, count)),
        WriterMsg::ClientEos => *eos = true,
        WriterMsg::ReaderGone => *gone = true,
    }
}

/// Pool-wide drain: route every tagged result to its connection's
/// writer. Polls nonblockingly while the server runs (it must watch the
/// shutdown flag — the pool's own threads still park per their
/// `WaitMode`); after shutdown it switches to the blocking
/// [`AccelPool::load_result`], whose `disconnect_grace` machinery
/// guarantees termination even if a lane leaked.
fn drain_loop<I: Send + 'static, O: Send + 'static>(
    mut pool: AccelPool<Tagged<I>, Tagged<O>>,
    reg_rx: mpsc::Receiver<WriterReg<O>>,
    shutdown: Arc<AtomicBool>,
) -> (TraceReport, Option<AccelError>) {
    let mut writers: HashMap<u32, mpsc::Sender<WriterMsg<Tagged<O>>>> = HashMap::new();
    let mut backoff = Backoff::new();
    let mut eos_sent = false;
    loop {
        while let Ok((id, tx)) = reg_rx.try_recv() {
            writers.insert(id, tx);
        }
        if !eos_sent && shutdown.load(Ordering::SeqCst) {
            pool.offload_eos();
            eos_sent = true;
        }
        if eos_sent {
            match pool.load_result() {
                Some(t) => route(&mut writers, &reg_rx, t),
                None => break,
            }
        } else {
            match pool.load_result_nb() {
                Some(t) => {
                    backoff.reset();
                    route(&mut writers, &reg_rx, t);
                }
                None => {
                    // Escalate spin → yield → sleep: results gone
                    // quiet, but keep shutdown latency ≪ read_tick.
                    if backoff.should_park(WaitMode::Adaptive, Duration::ZERO) {
                        thread::sleep(Duration::from_micros(500));
                    } else {
                        backoff.snooze();
                    }
                }
            }
        }
    }
    match pool.wait_checked() {
        Ok(trace) => (trace, None),
        Err(e) => (TraceReport::default(), Some(e)),
    }
}

fn route<O>(
    writers: &mut HashMap<u32, mpsc::Sender<WriterMsg<Tagged<O>>>>,
    reg_rx: &mpsc::Receiver<WriterReg<O>>,
    t: Tagged<O>,
) {
    // Registrations are sent before a connection's first offload, so a
    // miss here only means the reg is still queued.
    if !writers.contains_key(&t.conn) {
        while let Ok((id, tx)) = reg_rx.try_recv() {
            writers.insert(id, tx);
        }
    }
    let conn = t.conn;
    if let Some(tx) = writers.get(&conn) {
        // A dead writer (connection torn down) just discards results.
        if tx.send(WriterMsg::Result(t)).is_err() {
            writers.remove(&conn);
        }
    }
}
