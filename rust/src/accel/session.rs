//! The single-client **session** protocol (paper §3, Fig. 3): one
//! sequential caller owns one accelerator and drives its cycles.
//!
//! The API mirrors the paper's Fig. 3 protocol:
//!
//! ```no_run
//! use fastflow::prelude::*;
//!
//! // ff::ff_farm<> farm(true /*accel*/); farm.add_workers(w);
//! let mut acc: FarmAccel<u64, u64> =
//!     farm(FarmConfig::default().workers(4), |_| seq_fn(|x: u64| x * x)).into_accel_frozen();
//!
//! // farm.offload(task);
//! for i in 0..100 {
//!     acc.offload(i).unwrap();
//! }
//! // farm.offload((void*)ff::FF_EOS);
//! acc.offload_eos();
//! // pop results from the accelerator output channel
//! let mut sum = 0;
//! while let Some(sq) = acc.load_result() {
//!     sum += sq;
//! }
//! acc.wait_freezing(); // frozen: threads OS-suspended, ready for thaw()
//! acc.thaw();          // next burst…
//! acc.offload_eos();
//! acc.wait_freezing();
//! let report = acc.wait(); // final join
//! # let _ = (sum, report);
//! ```
//!
//! For many concurrent offloaders, see [`crate::accel::client`] and
//! [`crate::accel::pool`] — the session stays the right tool when one
//! thread drives the device, and is what each pool shard runs inside.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use super::AccelError;
use crate::channel::Msg;
use crate::farm::{farm, FarmConfig};
use crate::node::{LifecycleState, Node, RunMode};
use crate::skeleton::builder::{seq, Skeleton};
use crate::skeleton::LaunchedSkeleton;
use crate::trace::{TraceReport, TraceRow};
use crate::util::WaitMode;

/// A software accelerator wrapping any launched skeleton.
///
/// Obtained from [`crate::skeleton::Skeleton::into_accel`] /
/// [`crate::skeleton::Skeleton::into_accel_frozen`] on any composed
/// skeleton (or [`Accel::from_skeleton`] around an explicit
/// [`crate::skeleton::Skeleton::launch`]).
pub struct Accel<I: Send + 'static, O: Send + 'static> {
    skel: LaunchedSkeleton<I, O>,
    /// Tasks offloaded in the current run cycle.
    pub offloaded: u64,
    /// Results popped in the current run cycle.
    pub collected: u64,
    /// EOS offloaded for the current cycle but cycle not yet finished.
    eos_sent: bool,
    /// The output stream of the current cycle reached EOS.
    out_drained: bool,
    /// Items of a partially-consumed `Msg::Batch` result frame.
    pending: VecDeque<O>,
}

/// Farm-shaped accelerator (the paper's main configuration).
pub type FarmAccel<I, O> = Accel<I, O>;

impl<I: Send + 'static, O: Send + 'static> Accel<I, O> {
    /// Wrap an already-launched skeleton as an accelerator.
    pub fn from_skeleton(skel: LaunchedSkeleton<I, O>) -> Self {
        Accel {
            skel,
            offloaded: 0,
            collected: 0,
            eos_sent: false,
            out_drained: false,
            pending: VecDeque::new(),
        }
    }

    /// Create **and run** a farm accelerator (one-shot: after EOS the
    /// threads exit; use [`Accel::wait`] to join).
    #[deprecated(
        since = "0.2.0",
        note = "use `farm(cfg, |w| seq(factory(w))).into_accel()`"
    )]
    pub fn run<W, F>(cfg: FarmConfig, mut factory: F) -> Self
    where
        W: Node<In = I, Out = O> + 'static,
        F: FnMut(usize) -> W,
    {
        farm(cfg, move |wi| seq(factory(wi))).into_accel()
    }

    /// Create and run a farm accelerator in **freeze** mode: after each
    /// EOS the threads park (OS-suspended) and can be [`Accel::thaw`]ed
    /// for the next burst — the paper's `run_then_freeze()`.
    #[deprecated(
        since = "0.2.0",
        note = "use `farm(cfg, |w| seq(factory(w))).into_accel_frozen()`"
    )]
    pub fn run_then_freeze<W, F>(cfg: FarmConfig, mut factory: F) -> Self
    where
        W: Node<In = I, Out = O> + 'static,
        F: FnMut(usize) -> W,
    {
        farm(cfg, move |wi| seq(factory(wi))).into_accel_frozen()
    }

    /// Collector-less variant (paper §4.2): worker outputs are
    /// discarded; results travel through shared state.
    #[deprecated(
        since = "0.2.0",
        note = "use `farm(cfg, |w| seq(factory(w))).no_collector().into_accel()`"
    )]
    pub fn run_no_collector<W, F>(cfg: FarmConfig, mut factory: F) -> Self
    where
        W: Node<In = I, Out = O> + 'static,
        F: FnMut(usize) -> W,
    {
        farm(cfg, move |wi| seq(factory(wi)))
            .no_collector()
            .into_accel()
    }

    /// Collector-less freeze-mode variant.
    #[deprecated(
        since = "0.2.0",
        note = "use `farm(cfg, |w| seq(factory(w))).no_collector().into_accel_frozen()`"
    )]
    pub fn run_then_freeze_no_collector<W, F>(cfg: FarmConfig, mut factory: F) -> Self
    where
        W: Node<In = I, Out = O> + 'static,
        F: FnMut(usize) -> W,
    {
        farm(cfg, move |wi| seq(factory(wi)))
            .no_collector()
            .into_accel_frozen()
    }

    /// Offload one task onto the accelerator (blocking on backpressure —
    /// the paper's `offload` blocks when the input channel is full).
    ///
    /// Errors with [`AccelError::Closed`] after [`Accel::offload_eos`]
    /// in the same cycle — in every build, not just with debug
    /// assertions (a release build must not silently push onto a
    /// closed stream) — and with [`AccelError::Disconnected`] once the
    /// skeleton is poisoned (see [`Accel::poisoned`]).
    #[inline]
    pub fn offload(&mut self, task: I) -> Result<(), AccelError> {
        if self.eos_sent {
            return Err(AccelError::Closed);
        }
        if self.skel.poisoned() {
            return Err(AccelError::Disconnected);
        }
        self.skel
            .input
            .send(task)
            .map_err(|_| AccelError::Disconnected)?;
        self.offloaded += 1;
        Ok(())
    }

    /// Draw a recycled batch buffer for [`Accel::offload_batch`]: the
    /// farm emitter returns every unpacked frame through the input
    /// stream's free lane, so a loop of `take_batch_buf` → fill →
    /// `offload_batch` allocates nothing after warmup (observable via
    /// [`Accel::batch_alloc_stats`] and the `offload` trace row).
    #[must_use = "the drawn buffer is the batch frame — fill and offload it"]
    pub fn take_batch_buf(&mut self) -> Vec<I> {
        self.skel.input.take_buf()
    }

    /// `(fresh, reused)` batch-buffer counts for the offload side.
    /// `fresh` plateaus after warmup when the emitter keeps returning
    /// emptied frames.
    pub fn batch_alloc_stats(&self) -> (u64, u64) {
        (self.skel.input.batch_fresh(), self.skel.input.batch_reused())
    }

    /// Offload a whole run of tasks as **one** stream frame (one queue
    /// slot, one synchronization). The farm emitter unpacks the batch,
    /// so scheduling policies and ordered collection still operate on
    /// individual tasks — batching only changes the transfer cost, not
    /// the semantics. This is what makes fine-grained offloading pay
    /// (cf. `benches/granularity.rs` and `benches/accel_multiclient.rs`).
    /// Draw `tasks` from [`Accel::take_batch_buf`] to keep sustained
    /// batching allocation-free.
    pub fn offload_batch(&mut self, tasks: Vec<I>) -> Result<(), AccelError> {
        if self.eos_sent {
            return Err(AccelError::Closed);
        }
        if self.skel.poisoned() {
            return Err(AccelError::Disconnected);
        }
        let n = tasks.len() as u64;
        self.skel
            .input
            .send_batch(tasks)
            .map_err(|_| AccelError::Disconnected)?;
        self.offloaded += n;
        Ok(())
    }

    /// Non-blocking offload. Fails with the same [`AccelError::Closed`]
    /// as [`Accel::offload`] once the cycle's EOS has been sent.
    #[inline]
    #[must_use = "on failure the task is handed back and must not be dropped"]
    pub fn try_offload(&mut self, task: I) -> Result<(), (I, AccelError)> {
        if self.eos_sent {
            return Err((task, AccelError::Closed));
        }
        if self.skel.poisoned() {
            return Err((task, AccelError::Disconnected));
        }
        if !self.skel.input.peer_alive() {
            return Err((task, AccelError::Disconnected));
        }
        match self.skel.input.try_send(task) {
            Ok(()) => {
                self.offloaded += 1;
                Ok(())
            }
            Err(crate::spsc::Full(t)) => Err((t, AccelError::WouldBlock)),
        }
    }

    /// Close the current input stream (the paper's
    /// `farm.offload((void*)FF_EOS)`).
    pub fn offload_eos(&mut self) {
        if !self.eos_sent {
            let _ = self.skel.input.send_eos();
            self.eos_sent = true;
        }
    }

    /// Pop one result, blocking. `None` when the current cycle's output
    /// stream is exhausted (EOS observed). On collector-less
    /// accelerators, returns `None` immediately.
    ///
    /// Blocking waits ride the receiver's shared [`crate::util::Backoff`]
    /// escalation (spin → yield — and, after [`Accel::set_wait`] with
    /// [`WaitMode::Adaptive`]/[`WaitMode::Park`], park on the output
    /// stream's doorbell), so a caller draining an idle accelerator does
    /// not burn its core.
    pub fn load_result(&mut self) -> Option<O> {
        loop {
            if let Some(v) = self.pending.pop_front() {
                self.collected += 1;
                return Some(v);
            }
            if self.out_drained {
                return None;
            }
            let rx = self.skel.output.as_mut()?;
            match rx.recv() {
                Msg::Task(v) => {
                    self.collected += 1;
                    return Some(v);
                }
                Msg::Batch(vs) => {
                    let pending = &mut self.pending;
                    rx.recycle_after(vs, |vs| pending.extend(vs.drain(..)));
                }
                Msg::Eos => {
                    self.out_drained = true;
                    return None;
                }
            }
        }
    }

    /// Pop one result if immediately available (the paper's non-blocking
    /// `load_result_nb`).
    #[must_use = "a popped result must be consumed (None may just mean not-ready-yet)"]
    pub fn load_result_nb(&mut self) -> Option<O> {
        loop {
            if let Some(v) = self.pending.pop_front() {
                self.collected += 1;
                return Some(v);
            }
            if self.out_drained {
                return None;
            }
            let rx = self.skel.output.as_mut()?;
            match rx.try_recv()? {
                Msg::Task(v) => {
                    self.collected += 1;
                    return Some(v);
                }
                Msg::Batch(vs) => {
                    let pending = &mut self.pending;
                    rx.recycle_after(vs, |vs| pending.extend(vs.drain(..)));
                }
                Msg::Eos => {
                    self.out_drained = true;
                    return None;
                }
            }
        }
    }

    /// Block until every accelerator thread is frozen (requires
    /// `run_then_freeze`). Drains nothing: pop results before or after.
    pub fn wait_freezing(&self) {
        self.skel.lifecycle.wait_freezing();
    }

    /// Wake a frozen accelerator for another burst; resets the per-cycle
    /// input/output stream state.
    pub fn thaw(&mut self) {
        assert_eq!(
            self.skel.lifecycle.mode(),
            RunMode::RunThenFreeze,
            "thaw on a run-to-end accelerator"
        );
        // The previous cycle's streams must be closed & drained.
        debug_assert!(self.eos_sent, "thaw before offload_eos");
        debug_assert!(
            self.pending.is_empty() && (self.out_drained || self.skel.output.is_none()),
            "thaw before draining the output stream to None (results would \
             bleed into the next cycle)"
        );
        self.skel.lifecycle.thaw();
        self.eos_sent = false;
        self.out_drained = false;
        self.offloaded = 0;
        self.collected = 0;
    }

    /// Final join (the paper's `farm.wait()`): closes the input stream if
    /// still open, drains any un-popped results, tells frozen threads to
    /// exit and joins them all. Returns the trace report (including the
    /// offload-side `offload` row).
    pub fn wait(mut self) -> TraceReport {
        self.offload_eos();
        // Drain the output so the collector can't block on a full queue.
        while self.load_result().is_some() {}
        let offload = self.offload_row();
        self.skel.lifecycle.request_exit();
        let mut report = self.skel.join();
        report.rows.push(offload);
        report
    }

    /// The caller-side row of the trace report: offload counts plus the
    /// batch-pool fresh/reused counters whose plateau shows the hot
    /// path is allocation-free.
    fn offload_row(&self) -> TraceRow {
        let (alloc_fresh, alloc_reused) = self.batch_alloc_stats();
        TraceRow {
            name: "offload".into(),
            tasks: self.offloaded,
            emitted: self.offloaded,
            svc_time: Duration::ZERO,
            push_retries: self.skel.input.push_retries,
            pop_retries: 0,
            cycles: 0,
            alloc_fresh,
            alloc_reused,
        }
    }

    /// True once the skeleton raised its poison flag (a worker violated
    /// the ordered farm's one-emission contract). The stream still
    /// drains; [`Accel::offload`]/[`Accel::try_offload`] surface
    /// [`AccelError::Disconnected`]. Check this on the load side after
    /// a short drain to distinguish "complete" from "poisoned".
    pub fn poisoned(&self) -> bool {
        self.skel.poisoned()
    }

    /// Observed lifecycle state.
    pub fn state(&self) -> LifecycleState {
        self.skel.lifecycle.state()
    }

    /// Trace snapshot (running accelerators included), with the
    /// caller-side `offload` row appended.
    pub fn trace_report(&self) -> TraceReport {
        let mut report = self.skel.trace_report();
        report.rows.push(self.offload_row());
        report
    }

    /// Number of accelerator threads (emitter + workers [+ collector]).
    pub fn threads(&self) -> usize {
        self.skel.lifecycle.threads()
    }

    /// Caller-side waiting discipline (see [`WaitMode`]): how
    /// [`Accel::load_result`] waits on an empty output stream and how
    /// [`Accel::offload`] waits on a full (bounded) input stream. The
    /// *accelerator threads'* discipline is configured where the
    /// skeleton is built — [`field@crate::farm::FarmConfig::wait`] or
    /// [`crate::skeleton::Skeleton::wait_mode`].
    pub fn set_wait(&mut self, mode: WaitMode) {
        self.skel.input.set_wait(mode);
        if let Some(rx) = self.skel.output.as_mut() {
            rx.set_wait(mode);
        }
    }

    /// Accelerator threads currently parked on stream doorbells (a racy
    /// snapshot; nonzero only when the skeleton was built with an
    /// `Adaptive`/`Park` [`WaitMode`]). Frozen threads sit in the
    /// lifecycle condvar and are *not* counted — check
    /// [`Accel::state`] for [`LifecycleState::Frozen`] instead.
    pub fn parked_threads(&self) -> usize {
        self.skel.park_gauge.parked_now()
    }

    /// Access the shared lifecycle (for advanced protocols).
    pub fn lifecycle(&self) -> &Arc<crate::node::Lifecycle> {
        &self.skel.lifecycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::farm::SchedPolicy;
    use crate::skeleton::seq_fn;

    #[test]
    fn one_shot_offload_and_drain() {
        let mut acc: FarmAccel<u64, u64> =
            farm(FarmConfig::default().workers(3), |_| seq_fn(|x: u64| x + 1)).into_accel();
        for i in 0..1000 {
            acc.offload(i).unwrap();
        }
        acc.offload_eos();
        let mut got = vec![];
        while let Some(v) = acc.load_result() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, (1..=1000).collect::<Vec<_>>());
        assert_eq!(acc.collected, 1000);
        let report = acc.wait();
        assert!(report.total_tasks() > 0);
    }

    #[test]
    fn offload_after_eos_is_closed() {
        let mut acc: FarmAccel<u64, u64> =
            farm(FarmConfig::default().workers(2), |_| seq_fn(|x: u64| x)).into_accel();
        acc.offload(1).unwrap();
        acc.offload_eos();
        assert_eq!(acc.offload(2), Err(AccelError::Closed));
        assert_eq!(acc.offload_batch(vec![4, 5]), Err(AccelError::Closed));
        match acc.try_offload(3) {
            Err((task, AccelError::Closed)) => assert_eq!(task, 3),
            other => panic!("expected Closed, got {other:?}"),
        }
        // The rejected offloads must not count, and the cycle still
        // drains and joins cleanly.
        assert_eq!(acc.offloaded, 1);
        let mut got = 0;
        while acc.load_result().is_some() {
            got += 1;
        }
        assert_eq!(got, 1);
        acc.wait();
    }

    #[test]
    fn thaw_reopens_input_after_closed() {
        let mut acc: FarmAccel<u64, u64> =
            farm(FarmConfig::default().workers(2), |_| seq_fn(|x: u64| x)).into_accel_frozen();
        acc.offload_eos();
        assert_eq!(acc.offload(1), Err(AccelError::Closed));
        while acc.load_result().is_some() {}
        acc.wait_freezing();
        acc.thaw();
        acc.offload(1).unwrap(); // next cycle accepts again
        acc.offload_eos();
        assert_eq!(acc.load_result(), Some(1));
        acc.wait();
    }

    #[test]
    fn freeze_thaw_multiple_bursts() {
        // The QT-Mandelbrot pattern: one accelerator reused across passes.
        let mut acc: FarmAccel<u64, u64> = farm(
            FarmConfig::default().workers(4).sched(SchedPolicy::OnDemand),
            |_| seq_fn(|x: u64| x * 10),
        )
        .into_accel_frozen();
        for burst in 0..5u64 {
            if burst > 0 {
                acc.thaw();
            }
            for i in 0..200 {
                acc.offload(burst * 1000 + i).unwrap();
            }
            acc.offload_eos();
            let mut sum = 0u64;
            let mut count = 0;
            while let Some(v) = acc.load_result() {
                sum += v;
                count += 1;
            }
            assert_eq!(count, 200);
            let expect: u64 = (0..200).map(|i| (burst * 1000 + i) * 10).sum();
            assert_eq!(sum, expect);
            acc.wait_freezing();
            assert_eq!(acc.state(), LifecycleState::Frozen);
        }
        acc.thaw();
        acc.offload_eos();
        acc.wait_freezing();
        acc.wait();
    }

    #[test]
    fn collectorless_accel_accumulates_shared_state() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let total = Arc::new(AtomicU64::new(0));
        let t2 = total.clone();
        let mut acc: FarmAccel<u64, ()> =
            farm(FarmConfig::default().workers(4), move |_| {
                let total = t2.clone();
                seq_fn(move |x: u64| {
                    total.fetch_add(x, Ordering::Relaxed);
                })
            })
            .no_collector()
            .into_accel();
        for i in 1..=100 {
            acc.offload(i).unwrap();
        }
        assert!(acc.load_result().is_none()); // no output stream
        acc.offload_eos();
        acc.wait();
        assert_eq!(total.load(Ordering::Relaxed), 5050);
    }

    #[test]
    fn try_offload_backpressure() {
        // Slow worker + tiny queues: try_offload must eventually WouldBlock.
        let mut acc: FarmAccel<u64, u64> = farm(
            FarmConfig::default().workers(1).queue_caps(1, 1, 1),
            |_| {
                seq_fn(|x: u64| {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    x
                })
            },
        )
        .into_accel();
        let mut would_block = false;
        for i in 0..64 {
            match acc.try_offload(i) {
                Ok(()) => {}
                Err((_, AccelError::WouldBlock)) => {
                    would_block = true;
                    break;
                }
                Err((_, e)) => panic!("unexpected: {e}"),
            }
        }
        assert!(would_block);
        acc.offload_eos();
        acc.wait();
    }

    #[test]
    fn wait_without_explicit_eos_still_joins() {
        let mut acc: FarmAccel<u64, u64> =
            farm(FarmConfig::default().workers(2), |_| seq_fn(|x: u64| x)).into_accel();
        acc.offload(1).unwrap();
        acc.offload(2).unwrap();
        // wait() sends EOS, drains, joins.
        let report = acc.wait();
        let workers: u64 = report
            .rows
            .iter()
            .filter(|r| r.name.starts_with("worker"))
            .map(|r| r.tasks)
            .sum();
        assert_eq!(workers, 2);
    }

    #[test]
    fn accel_state_transitions() {
        let mut acc: FarmAccel<u64, u64> =
            farm(FarmConfig::default().workers(2), |_| seq_fn(|x: u64| x)).into_accel_frozen();
        assert_eq!(acc.state(), LifecycleState::Running);
        acc.offload_eos();
        acc.wait_freezing();
        assert_eq!(acc.state(), LifecycleState::Frozen);
        acc.wait();
    }

    #[test]
    fn offload_batch_equals_per_item() {
        let mut acc: FarmAccel<u64, u64> = farm(
            FarmConfig::default().workers(3).ordered(),
            |_| seq_fn(|x: u64| x + 7),
        )
        .into_accel();
        acc.offload(0).unwrap();
        acc.offload_batch((1..100).collect()).unwrap();
        acc.offload_batch(vec![]).unwrap(); // no-op
        acc.offload(100).unwrap();
        assert_eq!(acc.offloaded, 101);
        acc.offload_eos();
        let mut got = vec![];
        while let Some(v) = acc.load_result() {
            got.push(v);
        }
        assert_eq!(got, (7..=107).collect::<Vec<_>>());
        assert_eq!(acc.collected, 101);
        acc.wait();
    }

    #[test]
    fn poisoned_ordered_accel_surfaces_disconnected() {
        use crate::node::{Node, Outbox, Svc};
        // A worker that violates the ordered farm's one-emission
        // contract on task 42: the farm poisons instead of panicking,
        // the offload side reports Disconnected, and the drain
        // terminates (regression for the old panic-and-maybe-hang).
        struct Rogue;
        impl Node for Rogue {
            type In = u64;
            type Out = u64;
            fn svc(&mut self, t: u64, out: &mut Outbox<'_, u64>) -> Svc {
                out.send(t);
                if t == 42 {
                    out.send(t); // contract violation
                }
                Svc::GoOn
            }
        }
        let mut acc: FarmAccel<u64, u64> =
            farm(FarmConfig::default().workers(1).ordered(), |_| seq(Rogue)).into_accel();
        let mut offload_err = None;
        for i in 0..10_000u64 {
            if let Err(e) = acc.offload(i) {
                offload_err = Some(e);
                break;
            }
        }
        acc.offload_eos();
        let mut drained = 0u64;
        while acc.load_result().is_some() {
            drained += 1;
        }
        assert!(acc.poisoned(), "load side must see the poison flag");
        // The offload side either saw Disconnected live or the caller
        // finished first; both are valid, but the flag always is set and
        // the drain always terminates with at least the pre-violation
        // results.
        if let Some(e) = offload_err {
            assert_eq!(e, AccelError::Disconnected);
        }
        assert!(drained >= 43, "results up to the violation must arrive");
        assert_eq!(acc.try_offload(7), Err((7, AccelError::Closed)));
        acc.wait();
    }
}
