//! The sharded **accelerator pool**: N independently-launched skeleton
//! accelerators behind one input arbiter and one merged result drain.
//!
//! One skeleton accelerator saturates once its emitter (one thread)
//! becomes the serialization point; the pool scales past that by
//! running `shards` complete skeleton instances — farms by default
//! ([`AccelPool::run`]), or **any** composed topology via
//! [`AccelPool::run_skeleton`] (e.g. a pool of per-shard pipelines
//! `decode.then(farm(…))`) — and placing offloaded work across them:
//!
//! * [`Placement::RoundRobin`] — stateless rotation, best for regular
//!   tasks;
//! * [`Placement::LeastLoaded`] — pick the shard with the fewest
//!   in-flight tasks, computed from two *single-writer* counters
//!   (arbiter-local `dispatched`, pool-side `completed`) so the data
//!   path still performs no atomic read-modify-write.
//!
//! Clients offload through cloneable [`AccelHandle`]s (private SPSC
//! lanes, see [`crate::accel::client`]); batched frames travel intact
//! from the client lane through placement into the chosen shard, whose
//! emitter unpacks them for scheduling.
//!
//! The pool-wide cycle protocol mirrors the single-client session:
//! `offload_eos()` closes the cycle once every handle has finished,
//! `wait_freezing()`/`thaw()` run freeze-mode bursts, `wait()` joins
//! everything and returns the merged trace report.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::client::{AccelHandle, LaneRegistry, NewLane};
use super::AccelError;
use crate::channel::{stream_unbounded, Msg, Receiver, Sender};
use crate::farm::{farm, FarmConfig};
use crate::node::{Lifecycle, Node, RunMode};
use crate::sched::MappingPolicy;
use crate::skeleton::builder::{seq, Skeleton};
use crate::skeleton::SkeletonHandle;
use crate::trace::{NodeTrace, TraceReport, TraceRow};
use crate::util::{Backoff, Doorbell, ParkGauge, WaitCfg, WaitMode};

/// Append a shard's trace rows prefixed `s<i>/` — shared by
/// [`AccelPool::trace_report`] and [`AccelPool::wait`].
fn merge_shard_rows(rows: &mut Vec<TraceRow>, shard: usize, rep: TraceReport) {
    rows.extend(rep.rows.into_iter().map(|mut r| {
        r.name = format!("s{shard}/{}", r.name);
        r
    }));
}

/// Shard-placement policy applied by the pool's input arbiter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Stateless rotation over the shards.
    #[default]
    RoundRobin,
    /// Send to the shard with the fewest in-flight tasks.
    LeastLoaded,
    /// Topology-aware packing: dispatch rotates like
    /// [`Placement::RoundRobin`], but each farm shard's threads are
    /// pinned into their **own LLC group**
    /// ([`MappingPolicy::Topology`]` { group: shard }`, spilling
    /// gracefully when shards > groups), so shards stop stealing each
    /// other's cache. Applies to the farm-shard constructors
    /// ([`AccelPool::run`] / [`AccelPool::run_then_freeze`]) when
    /// [`field@FarmConfig::mapping`] was left at `None`; `run_skeleton`
    /// shards own their topology — set a mapping inside the factory.
    /// Placement is perf-only: results stay bit-identical.
    Topology,
}

/// Pool configuration: how many shards, how each shard's farm is built,
/// how work is placed, the default client coalescing threshold, and the
/// waiting/elasticity discipline.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    pub shards: usize,
    pub placement: Placement,
    /// Default auto-coalescing threshold for handles created by this
    /// pool (1 = ship every task as its own frame).
    pub batch: usize,
    /// Per-shard farm topology (workers, scheduling, ordering, queues).
    pub farm: FarmConfig,
    /// Waiting discipline for the arbiter, the merged drain, and (for
    /// the farm-shard constructors) every shard thread — see
    /// [`WaitMode`]. `Park` gives the pool **idle-shard elasticity**: a
    /// shard whose lanes stay empty past [`field@PoolConfig::idle_grace`]
    /// parks wholesale (emitter, workers and collector each on their
    /// stream doorbell) and is woken by the arbiter's next dispatch.
    pub wait: WaitMode,
    /// How long a shard's lanes must stay empty before its threads
    /// park (zero = park as soon as the spin budget runs out).
    pub idle_grace: Duration,
    /// Parking modes (`Adaptive`/`Park`) only: how long the merged
    /// drain tolerates a fully stalled cycle (pool closed, no results,
    /// unfinished lanes) before
    /// concluding a client handle was leaked, force-closing the
    /// abandoned lanes and surfacing [`AccelError::Disconnected`]
    /// through [`AccelPool::wait_checked`].
    pub disconnect_grace: Duration,
}

/// Default per-shard worker budget: the machine's single-farm default
/// (`num_cpus - 1`) divided across the shards, so
/// `PoolConfig::default()` does not oversubscribe the host.
fn default_workers_per_shard(shards: usize) -> usize {
    ((crate::util::num_cpus().max(2) - 1) / shards.max(1)).max(1)
}

impl Default for PoolConfig {
    fn default() -> Self {
        let shards = 2;
        PoolConfig {
            shards,
            placement: Placement::default(),
            batch: 1,
            farm: FarmConfig::default().workers(default_workers_per_shard(shards)),
            wait: WaitMode::Spin,
            idle_grace: Duration::ZERO,
            disconnect_grace: Duration::from_millis(500),
        }
    }
}

impl PoolConfig {
    /// Set the shard count. While the worker budget is still the
    /// default it is rescaled across the new shard count — call
    /// [`PoolConfig::workers_per_shard`] / [`PoolConfig::farm`] *after*
    /// `shards` to override it.
    #[must_use]
    pub fn shards(mut self, n: usize) -> Self {
        let was_default = self.farm.workers == default_workers_per_shard(self.shards);
        self.shards = n.max(1);
        if was_default {
            self.farm.workers = default_workers_per_shard(self.shards);
        }
        self
    }
    #[must_use]
    pub fn placement(mut self, p: Placement) -> Self {
        self.placement = p;
        self
    }
    #[must_use]
    pub fn batch(mut self, b: usize) -> Self {
        self.batch = b.max(1);
        self
    }
    #[must_use]
    pub fn farm(mut self, cfg: FarmConfig) -> Self {
        self.farm = cfg;
        self
    }
    /// Convenience: set each shard's worker count.
    #[must_use]
    pub fn workers_per_shard(mut self, n: usize) -> Self {
        self.farm.workers = n.max(1);
        self
    }
    /// Waiting discipline for the whole pool (see [`field@PoolConfig::wait`]).
    #[must_use]
    pub fn wait(mut self, mode: WaitMode) -> Self {
        self.wait = mode;
        self
    }
    /// Idle-shard elasticity grace (see [`field@PoolConfig::idle_grace`]).
    #[must_use]
    pub fn idle_grace(mut self, grace: Duration) -> Self {
        self.idle_grace = grace;
        self
    }
    /// Leaked-handle detection window (see
    /// [`field@PoolConfig::disconnect_grace`]).
    #[must_use]
    pub fn disconnect_grace(mut self, grace: Duration) -> Self {
        self.disconnect_grace = grace;
        self
    }

    /// Launch a one-shot pool whose shards are arbitrary skeletons —
    /// `self.run_skeleton(|shard| skel)` sugar for
    /// [`AccelPool::run_skeleton`]. The per-shard [`PoolConfig::farm`]
    /// config is ignored (the factory decides each shard's topology);
    /// `shards`, `placement`, and `batch` apply unchanged.
    pub fn run_skeleton<I, O, S, F>(self, factory: F) -> (AccelPool<I, O>, AccelHandle<I>)
    where
        I: Send + 'static,
        O: Send + 'static,
        S: Skeleton<I, O>,
        F: FnMut(usize) -> S,
    {
        AccelPool::run_skeleton(self, factory)
    }
}

/// Pool → arbiter control frames.
enum Ctl {
    /// Close the current cycle once every client lane has finished.
    CloseCycle,
    /// Leaked-handle recovery (parking modes): drain whatever the
    /// still-open lanes buffered, then close them unconditionally and
    /// count them as abandoned, so the cycle can complete.
    ForceClose,
}

/// How many frames the arbiter drains from one lane before moving on —
/// bounds per-client latency while keeping hot lanes cheap to serve.
const LANE_BURST: usize = 64;

/// A sharded multi-client accelerator service. Create with
/// [`AccelPool::run`] (one-shot) or [`AccelPool::run_then_freeze`]
/// (burst reuse); offload through [`AccelHandle`]s; drain with
/// [`AccelPool::load_result`].
///
/// Protocol: the cycle's result stream ends only after (a) the pool
/// called [`AccelPool::offload_eos`] and (b) every handle created for
/// the cycle was finished or dropped — close your clients before
/// expecting the drain to terminate.
pub struct AccelPool<I: Send + 'static, O: Send + 'static> {
    mode: RunMode,
    batch: usize,
    registry: Arc<LaneRegistry<I>>,
    ctl: Sender<Ctl>,
    arbiter_lc: Arc<Lifecycle>,
    arbiter_trace: Arc<NodeTrace>,
    arbiter_join: Option<JoinHandle<()>>,
    shards: Vec<SkeletonHandle>,
    outputs: Vec<Receiver<O>>,
    /// Per-shard results consumed by the pool — the single-writer
    /// counterpart of the arbiter's `dispatched` counters (plain
    /// load+store, no RMW; the arbiter only reads them).
    completed: Arc<Vec<AtomicU64>>,
    out_done: Vec<bool>,
    done_count: usize,
    cursor: usize,
    /// Items of a partially-consumed batch result frame, tagged with
    /// their shard for completion accounting.
    pending: VecDeque<(usize, O)>,
    eos_sent: bool,
    /// Results popped in the current run cycle.
    pub collected: u64,
    /// The merged drain's waiting discipline (mode + disconnect grace).
    wait: WaitCfg,
    disconnect_grace: Duration,
    /// Set once a ForceClose was sent for this cycle.
    force_closed: bool,
    /// Lanes the arbiter force-closed (cumulative) — written by the
    /// arbiter, read by the pool.
    abandoned: Arc<AtomicU64>,
    /// Snapshot of `abandoned` at the start of the current cycle.
    abandoned_seen: u64,
    /// Parked-thread gauge for the arbiter thread.
    arbiter_gauge: Arc<ParkGauge>,
}

impl<I: Send + 'static, O: Send + 'static> AccelPool<I, O> {
    /// Launch a one-shot pool (threads exit after the cycle; join with
    /// [`AccelPool::wait`]). The factory builds one worker node per
    /// `(shard, worker)` slot. Returns the pool and a first client
    /// handle — `clone()` it for more clients.
    pub fn run<W, F>(cfg: PoolConfig, mut factory: F) -> (Self, AccelHandle<I>)
    where
        W: Node<In = I, Out = O> + 'static,
        F: FnMut(usize, usize) -> W,
    {
        let farm_cfg = Self::shard_farm_cfg(&cfg);
        let placement = cfg.placement;
        Self::launch(cfg, RunMode::RunToEnd, move |si| {
            let fc = Self::place_shard(farm_cfg.clone(), placement, si);
            farm(fc, |wi| seq(factory(si, wi)))
        })
    }

    /// Launch a pool in freeze mode: after each pool-wide EOS the
    /// threads park and can be [`AccelPool::thaw`]ed for the next burst.
    pub fn run_then_freeze<W, F>(cfg: PoolConfig, mut factory: F) -> (Self, AccelHandle<I>)
    where
        W: Node<In = I, Out = O> + 'static,
        F: FnMut(usize, usize) -> W,
    {
        let farm_cfg = Self::shard_farm_cfg(&cfg);
        let placement = cfg.placement;
        Self::launch(cfg, RunMode::RunThenFreeze, move |si| {
            let fc = Self::place_shard(farm_cfg.clone(), placement, si);
            farm(fc, |wi| seq(factory(si, wi)))
        })
    }

    /// [`Placement::Topology`]: pack farm shard `si` into its own LLC
    /// group unless the caller already chose a mapping explicitly.
    fn place_shard(mut fc: FarmConfig, placement: Placement, si: usize) -> FarmConfig {
        if placement == Placement::Topology && fc.mapping == MappingPolicy::None {
            fc.mapping = MappingPolicy::Topology { group: si };
        }
        fc
    }

    /// The per-shard farm config with the pool's waiting discipline
    /// folded in (more patient mode wins; the pool's idle grace becomes
    /// the shards' park grace). `run_skeleton` shards, whose topology
    /// the factory owns, inherit the pool mode only at the pool edges —
    /// set [`field@FarmConfig::wait`] / [`Skeleton::wait_mode`] inside the
    /// factory for shard-internal parking.
    fn shard_farm_cfg(cfg: &PoolConfig) -> FarmConfig {
        let mut farm_cfg = cfg.farm.clone();
        farm_cfg.wait = farm_cfg.wait.max(cfg.wait);
        if !cfg.idle_grace.is_zero() {
            farm_cfg.park_grace = cfg.idle_grace;
        }
        farm_cfg
    }

    /// Launch a one-shot pool whose shards are **arbitrary skeletons**:
    /// `factory(shard)` builds each shard's topology — a pipeline, a
    /// nested farm, a feedback loop, anything composed from the
    /// [`Skeleton`] algebra. Placement, batching, and the merged drain
    /// are identical to the farm-shard pool. Note that a shard whose
    /// outermost component is a `seq`/pipeline has a *bounded* input
    /// queue, so a backlogged shard can briefly stall the arbiter
    /// (farm-led shards keep the unbounded offload buffer).
    pub fn run_skeleton<S, F>(cfg: PoolConfig, factory: F) -> (Self, AccelHandle<I>)
    where
        S: Skeleton<I, O>,
        F: FnMut(usize) -> S,
    {
        Self::launch(cfg, RunMode::RunToEnd, factory)
    }

    /// Freeze-mode counterpart of [`AccelPool::run_skeleton`].
    pub fn run_skeleton_then_freeze<S, F>(cfg: PoolConfig, factory: F) -> (Self, AccelHandle<I>)
    where
        S: Skeleton<I, O>,
        F: FnMut(usize) -> S,
    {
        Self::launch(cfg, RunMode::RunThenFreeze, factory)
    }

    fn launch<S, F>(cfg: PoolConfig, mode: RunMode, mut factory: F) -> (Self, AccelHandle<I>)
    where
        S: Skeleton<I, O>,
        F: FnMut(usize) -> S,
    {
        let nshards = cfg.shards.max(1);
        let arbiter_gauge = Arc::new(ParkGauge::new());
        let arbiter_wait = WaitCfg {
            mode: cfg.wait,
            grace: cfg.idle_grace,
            gauge: if cfg.wait == WaitMode::Spin {
                None
            } else {
                Some(arbiter_gauge.clone())
            },
        };
        let mut shard_inputs = Vec::with_capacity(nshards);
        let mut outputs = Vec::with_capacity(nshards);
        let mut shards = Vec::with_capacity(nshards);
        for si in 0..nshards {
            let skel = factory(si).launch(mode);
            let (mut input, output, handle) = skel.split();
            let mut output = output.expect(
                "pool shards must produce an output stream — a collector-less \
                 farm cannot be a pool shard (its results bypass the drain)",
            );
            if cfg.wait != WaitMode::Spin {
                // Pool-edge waits: the arbiter blocking on a bounded
                // shard input, and the merged drain on the outputs.
                input.set_wait(cfg.wait);
                input.set_park_gauge(arbiter_gauge.clone());
                output.set_wait(cfg.wait);
            }
            shard_inputs.push(input);
            outputs.push(output);
            shards.push(handle);
        }
        let completed: Arc<Vec<AtomicU64>> =
            Arc::new((0..nshards).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
        let abandoned = Arc::new(AtomicU64::new(0));
        let (registry, reg_rx) = LaneRegistry::create();
        let (ctl_tx, ctl_rx) = stream_unbounded::<Ctl>();
        let arbiter_lc = Lifecycle::new(1, mode);
        let arbiter_trace = NodeTrace::new();
        let arbiter_join = spawn_arbiter(
            shard_inputs,
            reg_rx,
            ctl_rx,
            cfg.placement,
            ArbiterShared {
                completed: completed.clone(),
                abandoned: abandoned.clone(),
                lifecycle: arbiter_lc.clone(),
                trace: arbiter_trace.clone(),
                wait: arbiter_wait.clone(),
            },
        );
        let pool = AccelPool {
            mode,
            batch: cfg.batch.max(1),
            registry,
            ctl: ctl_tx,
            arbiter_lc,
            arbiter_trace,
            arbiter_join: Some(arbiter_join),
            shards,
            outputs,
            completed,
            out_done: vec![false; nshards],
            done_count: 0,
            cursor: 0,
            pending: VecDeque::new(),
            eos_sent: false,
            collected: 0,
            wait: WaitCfg {
                gauge: None, // the drain runs on the caller's thread
                ..arbiter_wait
            },
            disconnect_grace: cfg.disconnect_grace,
            force_closed: false,
            abandoned,
            abandoned_seen: 0,
            arbiter_gauge,
        };
        let handle = pool.handle();
        (pool, handle)
    }

    /// Open another client handle for the current cycle (equivalent to
    /// cloning an existing one). Panics after [`AccelPool::offload_eos`]
    /// — thaw into the next cycle first.
    pub fn handle(&self) -> AccelHandle<I> {
        assert!(
            !self.eos_sent,
            "AccelPool::handle() after offload_eos (thaw the next cycle first)"
        );
        AccelHandle::new(self.registry.clone(), self.batch)
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.outputs.len()
    }

    /// Pool-wide end-of-stream: after this, the cycle closes as soon as
    /// every client handle has finished (or been dropped). Idempotent
    /// within a cycle.
    pub fn offload_eos(&mut self) {
        if !self.eos_sent {
            let _ = self.ctl.send(Ctl::CloseCycle);
            self.eos_sent = true;
        }
    }

    /// Single-writer completion counter bump (no RMW: the pool is the
    /// only writer, the arbiter only reads).
    fn note_completed(&self, shard: usize) {
        let c = &self.completed[shard];
        c.store(c.load(Ordering::Relaxed) + 1, Ordering::Relaxed);
    }

    /// Pop one merged result if immediately available, polling the
    /// shards round-robin from the last productive one.
    #[must_use = "a popped result must be consumed (None may just mean not-ready-yet)"]
    pub fn load_result_nb(&mut self) -> Option<O> {
        if let Some((s, v)) = self.pending.pop_front() {
            self.note_completed(s);
            self.collected += 1;
            return Some(v);
        }
        let n = self.outputs.len();
        if self.done_count == n {
            return None;
        }
        for k in 0..n {
            let s = (self.cursor + k) % n;
            if self.out_done[s] {
                continue;
            }
            match self.outputs[s].try_recv() {
                Some(Msg::Task(v)) => {
                    self.cursor = s; // keep draining the hot shard
                    self.note_completed(s);
                    self.collected += 1;
                    return Some(v);
                }
                Some(Msg::Batch(vs)) => {
                    self.cursor = s;
                    // The emptied frame goes back to the shard's
                    // collector through the free lane.
                    let pending = &mut self.pending;
                    self.outputs[s]
                        .recycle_after(vs, |vs| pending.extend(vs.drain(..).map(|v| (s, v))));
                    if let Some((s2, v)) = self.pending.pop_front() {
                        self.note_completed(s2);
                        self.collected += 1;
                        return Some(v);
                    }
                }
                Some(Msg::Eos) => {
                    self.out_done[s] = true;
                    self.done_count += 1;
                }
                None => {
                    // A shard whose collector died without EOS must not
                    // wedge the merged drain.
                    if !self.outputs[s].peer_alive() && !self.outputs[s].has_next() {
                        self.out_done[s] = true;
                        self.done_count += 1;
                    }
                }
            }
        }
        None
    }

    /// Pop one merged result, blocking until one arrives or every
    /// shard's cycle output reached EOS (`None`). Idle waits use the
    /// shared [`Backoff`] escalation — and, under a `Park`-mode pool,
    /// park on any shard output's doorbell — so draining a quiet pool
    /// does not burn the caller's core.
    ///
    /// In the parking modes this is also where **leaked-handle
    /// recovery** runs: a cycle that is closed (`offload_eos` sent), produces
    /// nothing for [`field@PoolConfig::disconnect_grace`], and still has
    /// registered-but-unfinished lanes (the registration-epoch gap) is
    /// wedged by a handle that will never close — `mem::forget`, or a
    /// handle stranded in a poisoned mutex. The drain then force-closes
    /// the abandoned lanes (the arbiter forwards whatever they
    /// buffered) so the cycle terminates; [`AccelPool::wait_checked`]
    /// surfaces it as [`AccelError::Disconnected`].
    pub fn load_result(&mut self) -> Option<O> {
        let mut backoff = Backoff::new();
        let mut stalled: Option<Instant> = None;
        loop {
            if let Some(v) = self.load_result_nb() {
                return Some(v);
            }
            if self.done_count == self.outputs.len() {
                return None;
            }
            if self.wait.mode != WaitMode::Spin
                && self.eos_sent
                && !self.force_closed
                && self.registry.opened() > self.registry.finished()
                && stalled.get_or_insert_with(Instant::now).elapsed() >= self.disconnect_grace
            {
                let _ = self.ctl.send(Ctl::ForceClose);
                self.force_closed = true;
            }
            if self.wait.wants_park(&mut backoff) {
                let bells: Vec<&Doorbell> = self
                    .outputs
                    .iter()
                    .enumerate()
                    .filter(|(s, _)| !self.out_done[*s])
                    .map(|(_, rx)| rx.data_bell())
                    .collect();
                let (outputs, out_done) = (&self.outputs, &self.out_done);
                self.wait.park_any(&bells, || {
                    !outputs.iter().enumerate().any(|(s, rx)| {
                        !out_done[s] && (rx.has_next() || !rx.peer_alive())
                    })
                });
            } else {
                backoff.snooze();
            }
        }
    }

    /// Block until every thread of every shard (and the arbiter) is
    /// frozen. Requires `run_then_freeze`.
    pub fn wait_freezing(&self) {
        for sh in &self.shards {
            sh.lifecycle.wait_freezing();
        }
        self.arbiter_lc.wait_freezing();
    }

    /// Wake the frozen pool for another burst; resets per-cycle state.
    pub fn thaw(&mut self) {
        assert_eq!(
            self.mode,
            RunMode::RunThenFreeze,
            "thaw on a run-to-end pool"
        );
        debug_assert!(self.eos_sent, "thaw before offload_eos");
        debug_assert!(
            self.pending.is_empty() && self.done_count == self.outputs.len(),
            "thaw before draining the merged output (results would bleed \
             into the next cycle)"
        );
        self.arbiter_lc.thaw();
        for sh in &self.shards {
            sh.lifecycle.thaw();
        }
        self.eos_sent = false;
        for d in self.out_done.iter_mut() {
            *d = false;
        }
        self.done_count = 0;
        self.collected = 0;
        self.force_closed = false;
        self.abandoned_seen = self.abandoned.load(Ordering::SeqCst);
    }

    /// True once any shard raised its poison flag (see
    /// [`crate::accel::Accel::poisoned`]).
    pub fn poisoned(&self) -> bool {
        self.shards.iter().any(|s| s.poisoned())
    }

    /// Total threads run by the pool (arbiter + all shard threads).
    pub fn threads(&self) -> usize {
        1 + self
            .shards
            .iter()
            .map(|s| s.lifecycle.threads())
            .sum::<usize>()
    }

    /// Pool threads currently parked on stream doorbells: the arbiter
    /// plus every shard thread (a racy snapshot; nonzero only under an
    /// `Adaptive`/`Park` pool). This is the observable behind the
    /// idle-shard elasticity claim: an idle `Park`-mode pool reaches
    /// `parked_threads() == threads()`.
    pub fn parked_threads(&self) -> usize {
        self.arbiter_gauge.parked_now()
            + self
                .shards
                .iter()
                .map(|s| s.park_gauge.parked_now())
                .sum::<usize>()
    }

    /// Client lanes the arbiter force-closed as abandoned in the
    /// current cycle (see [`AccelPool::load_result`]).
    pub fn abandoned_lanes(&self) -> u64 {
        self.abandoned.load(Ordering::SeqCst) - self.abandoned_seen
    }

    /// Merged trace snapshot: the arbiter plus every shard's nodes,
    /// shard rows prefixed `s<i>/`.
    pub fn trace_report(&self) -> TraceReport {
        let mut rows = vec![self.arbiter_trace.snapshot("arbiter")];
        for (i, sh) in self.shards.iter().enumerate() {
            merge_shard_rows(&mut rows, i, sh.trace_report());
        }
        TraceReport { rows }
    }

    /// Final join: sends the pool-wide EOS, drains remaining results,
    /// tells frozen threads to exit and joins them all. All client
    /// handles must already be finished (or dropped) — the drain waits
    /// for their lanes to close (in the parking modes, a lane wedged by a
    /// *leaked* handle is force-closed after
    /// [`field@PoolConfig::disconnect_grace`]; use [`AccelPool::wait_checked`]
    /// to observe that as an error).
    pub fn wait(mut self) -> TraceReport {
        self.finish().0
    }

    /// Like [`AccelPool::wait`], but surfaces leaked-handle recovery:
    /// `Err(AccelError::Disconnected)` if any client lane of the final
    /// cycle had to be force-closed because its handle never ran its
    /// close path (`mem::forget`, a handle stranded in a poisoned
    /// mutex). The pool is fully drained and joined either way.
    pub fn wait_checked(mut self) -> Result<TraceReport, AccelError> {
        let (report, err) = self.finish();
        match err {
            None => Ok(report),
            Some(e) => Err(e),
        }
    }

    fn finish(&mut self) -> (TraceReport, Option<AccelError>) {
        self.offload_eos();
        while self.load_result().is_some() {}
        let err = if self.abandoned_lanes() > 0 {
            Some(AccelError::Disconnected)
        } else {
            None
        };
        self.arbiter_lc.request_exit();
        for sh in &self.shards {
            sh.lifecycle.request_exit();
        }
        if let Some(j) = self.arbiter_join.take() {
            let _ = j.join();
        }
        let mut rows = vec![self.arbiter_trace.snapshot("arbiter")];
        for (i, sh) in self.shards.drain(..).enumerate() {
            merge_shard_rows(&mut rows, i, sh.join());
        }
        (TraceReport { rows }, err)
    }
}

impl<I: Send + 'static, O: Send + 'static> Drop for AccelPool<I, O> {
    /// A pool dropped without [`AccelPool::wait`] must not leak
    /// OS-suspended threads: in freeze mode the shards would otherwise
    /// park forever after the arbiter's pool-dropped EOS. Telling every
    /// lifecycle to exit lets them run out instead (idempotent after
    /// `wait()`, which already drained `shards`).
    fn drop(&mut self) {
        self.arbiter_lc.request_exit();
        for sh in &self.shards {
            sh.lifecycle.request_exit();
        }
    }
}

/// Choose a shard for the next task/batch.
#[inline]
fn pick_shard(
    placement: Placement,
    rr: &mut usize,
    dispatched: &[u64],
    completed: &[AtomicU64],
) -> usize {
    let n = dispatched.len();
    match placement {
        // Topology placement affects where shard *threads* live, not
        // where tasks go — dispatch rotates exactly like RoundRobin.
        Placement::RoundRobin | Placement::Topology => {
            let s = *rr;
            *rr = (*rr + 1) % n;
            s
        }
        Placement::LeastLoaded => {
            let mut best = 0usize;
            let mut best_load = u64::MAX;
            for (i, d) in dispatched.iter().enumerate() {
                // `completed` counts *results* while `dispatched` counts
                // *tasks*; workers are allowed to emit 0 or ≥2 results
                // per task (arrival-ordered farms), so the delta is a
                // load heuristic, not an invariant — saturate it.
                let load = d.saturating_sub(completed[i].load(Ordering::Relaxed));
                if load < best_load {
                    best_load = load;
                    best = i;
                }
            }
            best
        }
    }
}

/// The shared state handed to the pool's input arbiter (bundled so the
/// spawn signature stays readable).
struct ArbiterShared {
    completed: Arc<Vec<AtomicU64>>,
    /// Client lanes force-closed as abandoned (leaked handles).
    abandoned: Arc<AtomicU64>,
    lifecycle: Arc<Lifecycle>,
    trace: Arc<NodeTrace>,
    wait: WaitCfg,
}

/// The pool's input arbiter: merges every client lane into the shard
/// inputs (SPMC over SPSC lanes, §2.3 — no locks, no RMW on the data
/// path) and applies the placement policy per task or per batch frame
/// (a batch stays whole so its single-synchronization economy survives
/// into the shard, whose emitter unpacks it for scheduling). Idle waits
/// — every lane empty, no control, no registrations — ride the shared
/// spin→yield→park escalation, parking on any lane/control doorbell;
/// any client offload rings the arbiter awake, which is what wakes a
/// wholesale-parked idle pool on the next dispatch.
fn spawn_arbiter<I: Send + 'static>(
    mut shard_inputs: Vec<Sender<I>>,
    mut reg_rx: Receiver<NewLane<I>>,
    mut ctl_rx: Receiver<Ctl>,
    placement: Placement,
    shared: ArbiterShared,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("ff-pool-arbiter".into())
        .spawn(move || {
            let ArbiterShared {
                completed,
                abandoned,
                lifecycle,
                trace,
                wait,
            } = shared;
            let nshards = shard_inputs.len();
            let mut rr = 0usize;
            // Cumulative per-shard dispatch counts: arbiter-local plain
            // integers (single writer — this thread), paired with the
            // pool-side `completed` atomics for in-flight load.
            let mut dispatched = vec![0u64; nshards];
            let mut exit_after_cycle = false;
            loop {
                // ---- one run cycle -----------------------------------
                let mut lanes: Vec<Receiver<I>> = Vec::new();
                let mut lane_open: Vec<bool> = Vec::new();
                let mut open = 0usize;
                let mut closing = false;
                let mut force_close = false;
                let mut backoff = Backoff::new();
                loop {
                    let mut progressed = false;
                    // 1. pool control
                    while let Some(m) = ctl_rx.try_recv() {
                        match m {
                            Msg::Task(Ctl::CloseCycle) | Msg::Eos => {
                                progressed = true;
                                closing = true;
                            }
                            Msg::Task(Ctl::ForceClose) => {
                                progressed = true;
                                closing = true;
                                force_close = true;
                            }
                            Msg::Batch(_) => unreachable!("control is never batched"),
                        }
                    }
                    if !ctl_rx.peer_alive() && !ctl_rx.has_next() {
                        // Pool dropped without wait(): finish the cycle
                        // with what we have and exit.
                        closing = true;
                        exit_after_cycle = true;
                    }
                    // 2. client lanes: burst-drain each open lane
                    for (li, lane) in lanes.iter_mut().enumerate() {
                        if !lane_open[li] {
                            continue;
                        }
                        for _ in 0..LANE_BURST {
                            match lane.try_recv() {
                                Some(Msg::Task(t)) => {
                                    progressed = true;
                                    let t0 = Instant::now();
                                    let s =
                                        pick_shard(placement, &mut rr, &dispatched, &completed);
                                    let _ = shard_inputs[s].send(t);
                                    dispatched[s] += 1;
                                    trace.on_task(t0.elapsed().as_nanos() as u64);
                                    trace.on_emit(1);
                                }
                                Some(Msg::Batch(ts)) => {
                                    progressed = true;
                                    let t0 = Instant::now();
                                    let k = ts.len() as u64;
                                    let s =
                                        pick_shard(placement, &mut rr, &dispatched, &completed);
                                    // Re-frame instead of forwarding the
                                    // client's Vec: the run moves into a
                                    // buffer recycled on the shard link
                                    // (returned by that shard's emitter)
                                    // and the client's buffer goes back
                                    // through its own lane — both return
                                    // paths stay SPSC and the arbiter
                                    // allocates nothing after warmup.
                                    let run = shard_inputs[s].reframe(lane, ts);
                                    let _ = shard_inputs[s].send_batch(run);
                                    dispatched[s] += k;
                                    trace.on_tasks(k, t0.elapsed().as_nanos() as u64);
                                    trace.on_emit(k);
                                }
                                Some(Msg::Eos) => {
                                    progressed = true;
                                    lane_open[li] = false;
                                    open -= 1;
                                    break;
                                }
                                None => {
                                    // A client thread that died without
                                    // closing (e.g. mem::forget) must not
                                    // wedge the cycle.
                                    if !lane.peer_alive() && !lane.has_next() {
                                        progressed = true;
                                        lane_open[li] = false;
                                        open -= 1;
                                    }
                                    break;
                                }
                            }
                        }
                    }
                    // 3. registrations — polled AFTER the lanes: popping
                    // a lane's Eos happens-after that client enqueued any
                    // clone registration, so a close can never outrun the
                    // clone it spawned.
                    while let Some(m) = reg_rx.try_recv() {
                        match m {
                            Msg::Task(NewLane(rx)) => {
                                progressed = true;
                                lanes.push(rx);
                                lane_open.push(true);
                                open += 1;
                            }
                            Msg::Batch(ls) => {
                                progressed = true;
                                for NewLane(rx) in ls {
                                    lanes.push(rx);
                                    lane_open.push(true);
                                    open += 1;
                                }
                            }
                            Msg::Eos => {}
                        }
                    }
                    // 4. leaked-handle recovery: after a ForceClose,
                    // close every drained lane unconditionally (frames
                    // still buffered were forwarded by step 2 above;
                    // the lane's handle will never send EOS).
                    if force_close {
                        for li in 0..lanes.len() {
                            if lane_open[li] && !lanes[li].has_next() {
                                lane_open[li] = false;
                                open -= 1;
                                abandoned.fetch_add(1, Ordering::SeqCst);
                                progressed = true;
                            }
                        }
                    }
                    // 5. cycle completion: pool closed + all lanes done.
                    if closing && open == 0 {
                        break;
                    }
                    if progressed {
                        backoff.reset();
                    } else if wait.wants_park(&mut backoff) {
                        // Everything idle: park until a client offload,
                        // a registration, or pool control rings.
                        let mut bells: Vec<&Doorbell> =
                            Vec::with_capacity(lanes.len() + 2);
                        bells.push(ctl_rx.data_bell());
                        bells.push(reg_rx.data_bell());
                        bells.extend(
                            lanes
                                .iter()
                                .enumerate()
                                .filter(|(li, _)| lane_open[*li])
                                .map(|(_, l)| l.data_bell()),
                        );
                        wait.park_any(&bells, || {
                            ctl_rx.peer_alive()
                                && !ctl_rx.has_next()
                                && !reg_rx.has_next()
                                && !lanes.iter().enumerate().any(|(li, l)| {
                                    lane_open[li] && (l.has_next() || !l.peer_alive())
                                })
                        });
                    } else {
                        backoff.snooze();
                    }
                }
                // Propagate EOS into every shard.
                for s in shard_inputs.iter_mut() {
                    let _ = s.send_eos();
                }
                // Publish the cycle's buffer-pool activity so the
                // fresh-allocation plateau is visible in TraceReport.
                let (mut fresh, mut reused) = (0u64, 0u64);
                for s in shard_inputs.iter_mut() {
                    let (f, r) = s.take_alloc_stats();
                    fresh += f;
                    reused += r;
                }
                trace.on_alloc(fresh, reused);
                trace.on_cycle();
                if exit_after_cycle || !lifecycle.cycle_end() {
                    break;
                }
            }
        })
        .expect("spawn pool arbiter")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::farm::{CollectorOrdering, SchedPolicy};
    use crate::node::node_fn;

    fn square_pool(shards: usize, batch: usize) -> (AccelPool<u64, u64>, AccelHandle<u64>) {
        AccelPool::run(
            PoolConfig::default()
                .shards(shards)
                .batch(batch)
                .workers_per_shard(2),
            |_s, _w| node_fn(|x: u64| x * x),
        )
    }

    #[test]
    fn single_client_pool_roundtrip() {
        let (mut pool, mut h) = square_pool(2, 1);
        for i in 0..500u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut got = vec![];
        while let Some(v) = pool.load_result() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, (0..500u64).map(|i| i * i).collect::<Vec<_>>());
        assert_eq!(pool.collected, 500);
        let report = pool.wait();
        let arb = report.rows.iter().find(|r| r.name == "arbiter").unwrap();
        assert_eq!(arb.tasks, 500);
    }

    #[test]
    fn four_clients_two_shards_exact_result_set() {
        // The acceptance shape: ≥4 handle clones on their own threads,
        // a 2-shard pool, exactly the sequential result set out.
        let (mut pool, root) = square_pool(2, 8);
        let clients = 4u64;
        let per_client = 1_000u64;
        let joins: Vec<_> = (0..clients)
            .map(|c| {
                let mut h = root.clone();
                std::thread::spawn(move || {
                    for i in 0..per_client {
                        h.offload(c * per_client + i).unwrap();
                    }
                    h.finish().unwrap();
                })
            })
            .collect();
        drop(root); // closes the root lane
        pool.offload_eos();
        let mut got = vec![];
        while let Some(v) = pool.load_result() {
            got.push(v);
        }
        for j in joins {
            j.join().unwrap();
        }
        got.sort_unstable();
        let mut expect: Vec<u64> = (0..clients * per_client).map(|i| i * i).collect();
        expect.sort_unstable();
        assert_eq!(got, expect);
        pool.wait();
    }

    #[test]
    fn least_loaded_placement_conserves_tasks() {
        let (mut pool, mut h) = AccelPool::run(
            PoolConfig::default()
                .shards(3)
                .placement(Placement::LeastLoaded)
                .workers_per_shard(1),
            |_s, _w| node_fn(|x: u64| x + 1),
        );
        for i in 0..2_000u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut count = 0u64;
        let mut sum = 0u64;
        while let Some(v) = pool.load_result() {
            count += 1;
            sum += v;
        }
        assert_eq!(count, 2_000);
        assert_eq!(sum, (1..=2_000u64).sum::<u64>());
        // Every shard should have been exercised.
        let report = pool.wait();
        for s in 0..3 {
            let emitter = report
                .rows
                .iter()
                .find(|r| r.name == format!("s{s}/emitter"))
                .unwrap();
            assert!(emitter.tasks > 0, "shard {s} never used");
        }
    }

    #[test]
    fn pool_freeze_thaw_bursts() {
        let (mut pool, first) = AccelPool::run_then_freeze(
            PoolConfig::default().shards(2).workers_per_shard(2),
            |_s, _w| node_fn(|x: u64| x + 1),
        );
        let mut next_handle = Some(first);
        for burst in 0..4u64 {
            let mut h = next_handle.take().unwrap();
            for i in 0..300u64 {
                h.offload(burst * 1_000 + i).unwrap();
            }
            h.finish().unwrap();
            pool.offload_eos();
            let mut sum = 0u64;
            let mut count = 0u64;
            while let Some(v) = pool.load_result() {
                sum += v;
                count += 1;
            }
            assert_eq!(count, 300, "burst {burst}");
            assert_eq!(sum, (0..300u64).map(|i| burst * 1_000 + i + 1).sum::<u64>());
            pool.wait_freezing();
            pool.thaw();
            next_handle = Some(pool.handle());
        }
        // Close the final (unused) cycle and join.
        next_handle.take().unwrap().finish().unwrap();
        pool.wait();
    }

    #[test]
    fn batched_offload_matches_per_item_per_shard_order() {
        // One shard + ordered collectors: per-client FIFO survives
        // coalescing end-to-end.
        let (mut pool, mut h) = AccelPool::run(
            PoolConfig::default()
                .shards(1)
                .batch(16)
                .farm(FarmConfig::default().workers(4).ordered()),
            |_s, _w| node_fn(|x: u64| x),
        );
        for i in 0..1_000u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut expect = 0u64;
        while let Some(v) = pool.load_result() {
            assert_eq!(v, expect);
            expect += 1;
        }
        assert_eq!(expect, 1_000);
        assert_eq!(
            pool.trace_report()
                .rows
                .iter()
                .find(|r| r.name == "s0/emitter")
                .unwrap()
                .tasks,
            1_000
        );
        pool.wait();
    }

    #[test]
    fn handle_after_eos_panics() {
        let (mut pool, h) = square_pool(1, 1);
        h.finish().unwrap();
        pool.offload_eos();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.handle()));
        assert!(r.is_err(), "handle() after offload_eos must panic");
        while pool.load_result().is_some() {}
        pool.wait();
    }

    #[test]
    fn empty_cycle_terminates() {
        let (mut pool, h) = square_pool(2, 4);
        drop(h);
        pool.offload_eos();
        assert!(pool.load_result().is_none());
        pool.wait();
    }

    #[test]
    fn pool_of_pipeline_shards_exactly_once() {
        // The api_redesign acceptance shape: every shard is a pipeline
        // (seq → farm), launched through the same pool plumbing.
        use crate::skeleton::seq_fn;
        let (mut pool, root) = AccelPool::run_skeleton(
            PoolConfig::default().shards(2).batch(4),
            |_shard| {
                seq_fn(|x: u64| x + 1).then(farm(
                    FarmConfig::default().workers(2).ordered(),
                    |_| seq_fn(|x: u64| x * 3),
                ))
            },
        );
        let clients = 3u64;
        let per_client = 500u64;
        let joins: Vec<_> = (0..clients)
            .map(|c| {
                let mut h = root.clone();
                std::thread::spawn(move || {
                    for i in 0..per_client {
                        h.offload(c * per_client + i).unwrap();
                    }
                    h.finish().unwrap();
                })
            })
            .collect();
        drop(root);
        pool.offload_eos();
        let total = clients * per_client;
        let mut seen = vec![false; total as usize];
        while let Some(v) = pool.load_result() {
            let orig = (v / 3) - 1;
            assert_eq!((orig + 1) * 3, v, "value not of pipeline shape: {v}");
            assert!(!seen[orig as usize], "duplicate {orig}");
            seen[orig as usize] = true;
        }
        for j in joins {
            j.join().unwrap();
        }
        assert!(seen.iter().all(|&s| s), "lost tasks");
        // Shard trace rows carry the pipeline's stage names.
        let report = pool.wait();
        assert!(report
            .rows
            .iter()
            .any(|r| r.name.starts_with("s0/stage-") || r.name.starts_with("s1/stage-")));
    }

    #[test]
    fn config_run_skeleton_sugar() {
        use crate::skeleton::seq_fn;
        let (mut pool, mut h) = PoolConfig::default()
            .shards(2)
            .run_skeleton(|_| seq_fn(|x: u64| x * 2));
        for i in 0..100u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut got = vec![];
        while let Some(v) = pool.load_result() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, (0..100u64).map(|i| i * 2).collect::<Vec<_>>());
        pool.wait();
    }

    #[test]
    fn ordering_config_passthrough() {
        // Smoke that PoolConfig::farm carries collector ordering.
        let cfg = PoolConfig::default()
            .shards(4)
            .placement(Placement::LeastLoaded)
            .batch(32)
            .farm(FarmConfig::default().workers(2).ordered());
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.placement, Placement::LeastLoaded);
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.farm.ordering, CollectorOrdering::Ordered);
        assert_eq!(cfg.farm.sched, SchedPolicy::RoundRobin);
    }
}
