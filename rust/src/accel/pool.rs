//! The sharded **accelerator pool**: N independently-launched skeleton
//! accelerators behind one input arbiter and one merged result drain.
//!
//! One skeleton accelerator saturates once its emitter (one thread)
//! becomes the serialization point; the pool scales past that by
//! running `shards` complete skeleton instances — farms by default
//! ([`AccelPool::run`]), or **any** composed topology via
//! [`AccelPool::run_skeleton`] (e.g. a pool of per-shard pipelines
//! `decode.then(farm(…))`) — and placing offloaded work across them:
//!
//! * [`Placement::RoundRobin`] — stateless rotation, best for regular
//!   tasks;
//! * [`Placement::LeastLoaded`] — pick the shard with the fewest
//!   in-flight tasks, computed from two *single-writer* counters
//!   (arbiter-local `dispatched`, pool-side `completed`) so the data
//!   path still performs no atomic read-modify-write.
//!
//! Clients offload through cloneable [`AccelHandle`]s (private SPSC
//! lanes, see [`crate::accel::client`]); batched frames travel intact
//! from the client lane through placement into the chosen shard, whose
//! emitter unpacks them for scheduling.
//!
//! The pool-wide cycle protocol mirrors the single-client session:
//! `offload_eos()` closes the cycle once every handle has finished,
//! `wait_freezing()`/`thaw()` run freeze-mode bursts, `wait()` joins
//! everything and returns the merged trace report.

use std::collections::VecDeque;
// ffaudit: allow(facade) — pool stat cells only (single-writer gauges
// and counters); every cross-thread edge in the pool rides the
// channels, not these atomics, so loom doubles would add model states
// without checking anything.
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::client::{AccelHandle, LaneRegistry, NewLane};
use super::job::{Job, JobBody, JobCtl, PRIORITY_LANES};
use super::AccelError;
use crate::alloc::BatchReturner;
use crate::channel::{stream_unbounded, Msg, Receiver, Sender};
use crate::farm::{farm, FarmConfig};
use crate::node::{Lifecycle, Node, RunMode};
use crate::sched::MappingPolicy;
use crate::skeleton::builder::{seq, Skeleton};
use crate::skeleton::SkeletonHandle;
use crate::trace::{NodeTrace, TraceReport, TraceRow};
use crate::util::{Backoff, Doorbell, ParkGauge, WaitCfg, WaitMode};

/// Append a shard's trace rows prefixed `s<i>/` — shared by
/// [`AccelPool::trace_report`] and [`AccelPool::wait`].
fn merge_shard_rows(rows: &mut Vec<TraceRow>, shard: usize, rep: TraceReport) {
    rows.extend(rep.rows.into_iter().map(|mut r| {
        r.name = format!("s{shard}/{}", r.name);
        r
    }));
}

/// Shard-placement policy applied by the pool's input arbiter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Stateless rotation over the shards.
    #[default]
    RoundRobin,
    /// Send to the shard with the fewest in-flight tasks.
    LeastLoaded,
    /// Topology-aware packing: dispatch rotates like
    /// [`Placement::RoundRobin`], but each farm shard's threads are
    /// pinned into their **own LLC group**
    /// ([`MappingPolicy::Topology`]` { group: shard }`, spilling
    /// gracefully when shards > groups), so shards stop stealing each
    /// other's cache. Applies to the farm-shard constructors
    /// ([`AccelPool::run`] / [`AccelPool::run_then_freeze`]) when
    /// [`field@FarmConfig::mapping`] was left at `None`; `run_skeleton`
    /// shards own their topology — set a mapping inside the factory.
    /// Placement is perf-only: results stay bit-identical.
    Topology,
}

/// Pool configuration: how many shards, how each shard's farm is built,
/// how work is placed, the default client coalescing threshold, and the
/// waiting/elasticity discipline.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    pub shards: usize,
    pub placement: Placement,
    /// Default auto-coalescing threshold for handles created by this
    /// pool (1 = ship every task as its own frame).
    pub batch: usize,
    /// Per-shard farm topology (workers, scheduling, ordering, queues).
    pub farm: FarmConfig,
    /// Waiting discipline for the arbiter, the merged drain, and (for
    /// the farm-shard constructors) every shard thread — see
    /// [`WaitMode`]. `Park` gives the pool **idle-shard elasticity**: a
    /// shard whose lanes stay empty past [`field@PoolConfig::idle_grace`]
    /// parks wholesale (emitter, workers and collector each on their
    /// stream doorbell) and is woken by the arbiter's next dispatch.
    pub wait: WaitMode,
    /// How long a shard's lanes must stay empty before its threads
    /// park (zero = park as soon as the spin budget runs out).
    pub idle_grace: Duration,
    /// Parking modes (`Adaptive`/`Park`) only: how long the merged
    /// drain tolerates a fully stalled cycle (pool closed, no results,
    /// unfinished lanes) before
    /// concluding a client handle was leaked, force-closing the
    /// abandoned lanes and surfacing [`AccelError::Disconnected`]
    /// through [`AccelPool::wait_checked`].
    pub disconnect_grace: Duration,
    /// Elastic dispatch (ISSUE 9): `Some` switches the input arbiter
    /// from eager forwarding to windowed dispatch with per-shard
    /// priority backlogs, work stealing, cancellation-at-dispatch and
    /// (optionally) shard autoscaling — see [`ElasticConfig`]. `None`
    /// (the default) keeps the legacy eager arbiter byte-for-byte.
    pub elastic: Option<ElasticConfig>,
}

/// Configuration of the **elastic** pool arbiter
/// ([`PoolConfig::elastic`]).
///
/// The elastic arbiter holds every admitted frame in a per-shard
/// backlog (one FIFO per [`super::Priority`] class) and dispatches into
/// a shard only while its in-flight window has room. That deferral is
/// what the rest of the machinery feeds on: idle shards **steal** from
/// the tail of overloaded siblings' backlogs, cancellation revokes
/// backlogged jobs before they ever reach a shard, priorities order the
/// deferred work (with an aging rule bounding how long any frame can be
/// overtaken), and the autoscaler grows/shrinks the set of shards that
/// receive work at all — parked shards (under `Adaptive`/`Park` pools)
/// are the warm standby tier of PR 5's `ParkGauge` elasticity.
///
/// Frames never split: a batch steals, cancels, and dispatches whole,
/// so per-handle runs stay intact and Spin-mode farm results remain
/// bit-identical to the steal-off pool (`tests/elastic.rs`).
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Let idle live shards pull whole frames from the *tail* of the
    /// most-backlogged sibling's lowest-priority lane.
    pub steal: bool,
    /// Grow/shrink the live shard count with offered load (see
    /// `grow_dwell` / `shrink_dwell` hysteresis). When `false` every
    /// shard is live from the start — the deterministic setting used by
    /// `benches/steal.rs`.
    pub autoscale: bool,
    /// Floor for the live shard count under autoscale (clamped to
    /// `1..=shards`).
    pub min_live: usize,
    /// Per-shard in-flight low-water mark, in items: a shard receives
    /// its next frame while `dispatched - completed < window`. A frame
    /// larger than the window still dispatches whole (the window gates
    /// *when*, never *whether*).
    pub window: u64,
    /// Starvation-freedom aging: every `age_every`-th dispatch of a
    /// shard serves its **oldest** backlogged frame regardless of
    /// priority class, so a `Low` frame is overtaken by at most
    /// `age_every - 1` dispatches per round. `0` disables aging.
    pub age_every: u64,
    /// Sustained-backlog time required before each grow step (and
    /// re-armed after it) — the anti-flap hysteresis on the way up.
    pub grow_dwell: Duration,
    /// Sustained-idle (no backlog, nothing in flight) time required
    /// before each shrink step — longer than `grow_dwell`, so the pool
    /// sheds capacity far more reluctantly than it adds it.
    pub shrink_dwell: Duration,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            steal: true,
            autoscale: true,
            min_live: 1,
            window: 4,
            age_every: 8,
            grow_dwell: Duration::from_micros(200),
            shrink_dwell: Duration::from_millis(2),
        }
    }
}

impl ElasticConfig {
    #[must_use]
    pub fn steal(mut self, on: bool) -> Self {
        self.steal = on;
        self
    }
    #[must_use]
    pub fn autoscale(mut self, on: bool) -> Self {
        self.autoscale = on;
        self
    }
    #[must_use]
    pub fn min_live(mut self, n: usize) -> Self {
        self.min_live = n.max(1);
        self
    }
    #[must_use]
    pub fn window(mut self, items: u64) -> Self {
        self.window = items.max(1);
        self
    }
    #[must_use]
    pub fn age_every(mut self, n: u64) -> Self {
        self.age_every = n;
        self
    }
    #[must_use]
    pub fn grow_dwell(mut self, d: Duration) -> Self {
        self.grow_dwell = d;
        self
    }
    #[must_use]
    pub fn shrink_dwell(mut self, d: Duration) -> Self {
        self.shrink_dwell = d;
        self
    }
}

/// A point-in-time snapshot of the pool's elasticity counters
/// ([`AccelPool::stats`]). All counters are cumulative over the pool's
/// lifetime and written single-writer by the arbiter (plain
/// load+store, no RMW); the snapshot is racy but internally cheap.
///
/// `#[non_exhaustive]`: more observables will be added.
#[derive(Debug, Clone, Copy)]
#[non_exhaustive]
pub struct PoolStats {
    /// Configured shard count.
    pub shards: usize,
    /// Shards currently receiving admissions (== `shards` on legacy
    /// eager pools and elastic pools without autoscale).
    pub live_shards: usize,
    /// Frames pulled by an idle shard from a sibling's backlog.
    pub steals: u64,
    /// Items those stolen frames carried.
    pub stolen_items: u64,
    /// Tracked jobs revoked before dispatch (cancel ≡ never-submitted).
    pub cancelled_jobs: u64,
    /// Items those cancelled jobs carried.
    pub cancelled_items: u64,
    /// Autoscaler grow steps.
    pub scale_ups: u64,
    /// Autoscaler shrink steps.
    pub scale_downs: u64,
    /// Jobs currently held back in the arbiter's backlogs (gauge,
    /// refreshed once per arbiter round).
    pub backlog: u64,
}

/// The arbiter-written cells behind [`PoolStats`]. Single writer (the
/// arbiter thread); the pool only loads. `bump`/`put` keep the crate's
/// no-RMW discipline: plain load + store.
#[derive(Debug, Default)]
struct StatsCells {
    live: AtomicU64,
    steals: AtomicU64,
    stolen_items: AtomicU64,
    cancelled_jobs: AtomicU64,
    cancelled_items: AtomicU64,
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
    backlog: AtomicU64,
}

impl StatsCells {
    #[inline]
    fn bump(cell: &AtomicU64, by: u64) {
        // ordering: stat — single-writer (arbiter) counter, no RMW needed.
        cell.store(cell.load(Ordering::Relaxed) + by, Ordering::Relaxed);
    }
    #[inline]
    fn put(cell: &AtomicU64, value: u64) {
        // ordering: stat — single-writer gauge overwrite.
        cell.store(value, Ordering::Relaxed);
    }
    /// Account a job whose cancel won the dispatch race: count it and
    /// return its batch buffer (items dropped — the job contributes
    /// nothing) through the owning lane's free lane.
    fn note_cancel<I>(&self, body: JobBody<I>, ret: &mut BatchReturner<I>) {
        Self::bump(&self.cancelled_jobs, 1);
        Self::bump(&self.cancelled_items, body.len() as u64);
        if let JobBody::Many(v) = body {
            ret.give(v);
        }
    }
}

/// Default per-shard worker budget: the machine's single-farm default
/// (`num_cpus - 1`) divided across the shards, so
/// `PoolConfig::default()` does not oversubscribe the host.
fn default_workers_per_shard(shards: usize) -> usize {
    ((crate::util::num_cpus().max(2) - 1) / shards.max(1)).max(1)
}

impl Default for PoolConfig {
    fn default() -> Self {
        let shards = 2;
        PoolConfig {
            shards,
            placement: Placement::default(),
            batch: 1,
            farm: FarmConfig::default().workers(default_workers_per_shard(shards)),
            wait: WaitMode::Spin,
            idle_grace: Duration::ZERO,
            disconnect_grace: Duration::from_millis(500),
            elastic: None,
        }
    }
}

impl PoolConfig {
    /// Set the shard count. While the worker budget is still the
    /// default it is rescaled across the new shard count — call
    /// [`PoolConfig::workers_per_shard`] / [`PoolConfig::farm`] *after*
    /// `shards` to override it.
    #[must_use]
    pub fn shards(mut self, n: usize) -> Self {
        let was_default = self.farm.workers == default_workers_per_shard(self.shards);
        self.shards = n.max(1);
        if was_default {
            self.farm.workers = default_workers_per_shard(self.shards);
        }
        self
    }
    #[must_use]
    pub fn placement(mut self, p: Placement) -> Self {
        self.placement = p;
        self
    }
    #[must_use]
    pub fn batch(mut self, b: usize) -> Self {
        self.batch = b.max(1);
        self
    }
    #[must_use]
    pub fn farm(mut self, cfg: FarmConfig) -> Self {
        self.farm = cfg;
        self
    }
    /// Convenience: set each shard's worker count.
    #[must_use]
    pub fn workers_per_shard(mut self, n: usize) -> Self {
        self.farm.workers = n.max(1);
        self
    }
    /// Waiting discipline for the whole pool (see [`field@PoolConfig::wait`]).
    #[must_use]
    pub fn wait(mut self, mode: WaitMode) -> Self {
        self.wait = mode;
        self
    }
    /// Idle-shard elasticity grace (see [`field@PoolConfig::idle_grace`]).
    #[must_use]
    pub fn idle_grace(mut self, grace: Duration) -> Self {
        self.idle_grace = grace;
        self
    }
    /// Leaked-handle detection window (see
    /// [`field@PoolConfig::disconnect_grace`]).
    #[must_use]
    pub fn disconnect_grace(mut self, grace: Duration) -> Self {
        self.disconnect_grace = grace;
        self
    }
    /// Switch the input arbiter to **elastic** dispatch (windowed
    /// backlogs, stealing, priorities, cancellation-at-dispatch,
    /// autoscale) — see [`ElasticConfig`].
    #[must_use]
    pub fn elastic(mut self, cfg: ElasticConfig) -> Self {
        self.elastic = Some(cfg);
        self
    }

    /// Launch a one-shot pool whose shards are arbitrary skeletons —
    /// `self.run_skeleton(|shard| skel)` sugar for
    /// [`AccelPool::run_skeleton`]. The per-shard [`PoolConfig::farm`]
    /// config is ignored (the factory decides each shard's topology);
    /// `shards`, `placement`, and `batch` apply unchanged.
    pub fn run_skeleton<I, O, S, F>(self, factory: F) -> (AccelPool<I, O>, AccelHandle<I>)
    where
        I: Send + 'static,
        O: Send + 'static,
        S: Skeleton<I, O>,
        F: FnMut(usize) -> S,
    {
        AccelPool::run_skeleton(self, factory)
    }
}

/// Pool → arbiter control frames.
enum Ctl {
    /// Close the current cycle once every client lane has finished.
    CloseCycle,
    /// Leaked-handle recovery (parking modes): drain whatever the
    /// still-open lanes buffered, then close them unconditionally and
    /// count them as abandoned, so the cycle can complete.
    ForceClose,
}

/// How many frames the arbiter drains from one lane before moving on —
/// bounds per-client latency while keeping hot lanes cheap to serve.
const LANE_BURST: usize = 64;

/// A sharded multi-client accelerator service. Create with
/// [`AccelPool::run`] (one-shot) or [`AccelPool::run_then_freeze`]
/// (burst reuse); offload through [`AccelHandle`]s; drain with
/// [`AccelPool::load_result`].
///
/// Protocol: the cycle's result stream ends only after (a) the pool
/// called [`AccelPool::offload_eos`] and (b) every handle created for
/// the cycle was finished or dropped — close your clients before
/// expecting the drain to terminate.
pub struct AccelPool<I: Send + 'static, O: Send + 'static> {
    mode: RunMode,
    batch: usize,
    registry: Arc<LaneRegistry<I>>,
    ctl: Sender<Ctl>,
    arbiter_lc: Arc<Lifecycle>,
    arbiter_trace: Arc<NodeTrace>,
    arbiter_join: Option<JoinHandle<()>>,
    shards: Vec<SkeletonHandle>,
    outputs: Vec<Receiver<O>>,
    /// Per-shard results consumed by the pool — the single-writer
    /// counterpart of the arbiter's `dispatched` counters (plain
    /// load+store, no RMW; the arbiter only reads them).
    completed: Arc<Vec<AtomicU64>>,
    out_done: Vec<bool>,
    done_count: usize,
    cursor: usize,
    /// Items of a partially-consumed batch result frame, tagged with
    /// their shard for completion accounting.
    pending: VecDeque<(usize, O)>,
    eos_sent: bool,
    /// Results popped in the current run cycle.
    pub collected: u64,
    /// The merged drain's waiting discipline (mode + disconnect grace).
    wait: WaitCfg,
    disconnect_grace: Duration,
    /// Set once a ForceClose was sent for this cycle.
    force_closed: bool,
    /// Lanes the arbiter force-closed (cumulative) — written by the
    /// arbiter, read by the pool.
    abandoned: Arc<AtomicU64>,
    /// Snapshot of `abandoned` at the start of the current cycle.
    abandoned_seen: u64,
    /// Parked-thread gauge for the arbiter thread.
    arbiter_gauge: Arc<ParkGauge>,
    /// Elasticity counters (arbiter-written, see [`PoolStats`]).
    stats: Arc<StatsCells>,
}

impl<I: Send + 'static, O: Send + 'static> AccelPool<I, O> {
    /// Launch a one-shot pool (threads exit after the cycle; join with
    /// [`AccelPool::wait`]). The factory builds one worker node per
    /// `(shard, worker)` slot. Returns the pool and a first client
    /// handle — `clone()` it for more clients.
    pub fn run<W, F>(cfg: PoolConfig, mut factory: F) -> (Self, AccelHandle<I>)
    where
        W: Node<In = I, Out = O> + 'static,
        F: FnMut(usize, usize) -> W,
    {
        let farm_cfg = Self::shard_farm_cfg(&cfg);
        let placement = cfg.placement;
        Self::launch(cfg, RunMode::RunToEnd, move |si| {
            let fc = Self::place_shard(farm_cfg.clone(), placement, si);
            farm(fc, |wi| seq(factory(si, wi)))
        })
    }

    /// Launch a pool in freeze mode: after each pool-wide EOS the
    /// threads park and can be [`AccelPool::thaw`]ed for the next burst.
    pub fn run_then_freeze<W, F>(cfg: PoolConfig, mut factory: F) -> (Self, AccelHandle<I>)
    where
        W: Node<In = I, Out = O> + 'static,
        F: FnMut(usize, usize) -> W,
    {
        let farm_cfg = Self::shard_farm_cfg(&cfg);
        let placement = cfg.placement;
        Self::launch(cfg, RunMode::RunThenFreeze, move |si| {
            let fc = Self::place_shard(farm_cfg.clone(), placement, si);
            farm(fc, |wi| seq(factory(si, wi)))
        })
    }

    /// [`Placement::Topology`]: pack farm shard `si` into its own LLC
    /// group unless the caller already chose a mapping explicitly.
    fn place_shard(mut fc: FarmConfig, placement: Placement, si: usize) -> FarmConfig {
        if placement == Placement::Topology && fc.mapping == MappingPolicy::None {
            fc.mapping = MappingPolicy::Topology { group: si };
        }
        fc
    }

    /// The per-shard farm config with the pool's waiting discipline
    /// folded in (more patient mode wins; the pool's idle grace becomes
    /// the shards' park grace). `run_skeleton` shards, whose topology
    /// the factory owns, inherit the pool mode only at the pool edges —
    /// set [`field@FarmConfig::wait`] / [`Skeleton::wait_mode`] inside the
    /// factory for shard-internal parking.
    fn shard_farm_cfg(cfg: &PoolConfig) -> FarmConfig {
        let mut farm_cfg = cfg.farm.clone();
        farm_cfg.wait = farm_cfg.wait.max(cfg.wait);
        if !cfg.idle_grace.is_zero() {
            farm_cfg.park_grace = cfg.idle_grace;
        }
        farm_cfg
    }

    /// Launch a one-shot pool whose shards are **arbitrary skeletons**:
    /// `factory(shard)` builds each shard's topology — a pipeline, a
    /// nested farm, a feedback loop, anything composed from the
    /// [`Skeleton`] algebra. Placement, batching, and the merged drain
    /// are identical to the farm-shard pool. Note that a shard whose
    /// outermost component is a `seq`/pipeline has a *bounded* input
    /// queue, so a backlogged shard can briefly stall the arbiter
    /// (farm-led shards keep the unbounded offload buffer).
    pub fn run_skeleton<S, F>(cfg: PoolConfig, factory: F) -> (Self, AccelHandle<I>)
    where
        S: Skeleton<I, O>,
        F: FnMut(usize) -> S,
    {
        Self::launch(cfg, RunMode::RunToEnd, factory)
    }

    /// Freeze-mode counterpart of [`AccelPool::run_skeleton`].
    pub fn run_skeleton_then_freeze<S, F>(cfg: PoolConfig, factory: F) -> (Self, AccelHandle<I>)
    where
        S: Skeleton<I, O>,
        F: FnMut(usize) -> S,
    {
        Self::launch(cfg, RunMode::RunThenFreeze, factory)
    }

    fn launch<S, F>(cfg: PoolConfig, mode: RunMode, mut factory: F) -> (Self, AccelHandle<I>)
    where
        S: Skeleton<I, O>,
        F: FnMut(usize) -> S,
    {
        let nshards = cfg.shards.max(1);
        let arbiter_gauge = Arc::new(ParkGauge::new());
        let arbiter_wait = WaitCfg {
            mode: cfg.wait,
            grace: cfg.idle_grace,
            gauge: if cfg.wait == WaitMode::Spin {
                None
            } else {
                Some(arbiter_gauge.clone())
            },
        };
        let mut shard_inputs = Vec::with_capacity(nshards);
        let mut outputs = Vec::with_capacity(nshards);
        let mut shards = Vec::with_capacity(nshards);
        for si in 0..nshards {
            let skel = factory(si).launch(mode);
            let (mut input, output, handle) = skel.split();
            let mut output = output.expect(
                "pool shards must produce an output stream — a collector-less \
                 farm cannot be a pool shard (its results bypass the drain)",
            );
            if cfg.wait != WaitMode::Spin {
                // Pool-edge waits: the arbiter blocking on a bounded
                // shard input, and the merged drain on the outputs.
                input.set_wait(cfg.wait);
                input.set_park_gauge(arbiter_gauge.clone());
                output.set_wait(cfg.wait);
            }
            shard_inputs.push(input);
            outputs.push(output);
            shards.push(handle);
        }
        let completed: Arc<Vec<AtomicU64>> =
            Arc::new((0..nshards).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
        let abandoned = Arc::new(AtomicU64::new(0));
        let stats = Arc::new(StatsCells::default());
        // Until (and unless) the elastic autoscaler says otherwise,
        // every shard is live.
        StatsCells::put(&stats.live, nshards as u64);
        let (registry, reg_rx) = LaneRegistry::create();
        let (ctl_tx, ctl_rx) = stream_unbounded::<Ctl>();
        let arbiter_lc = Lifecycle::new(1, mode);
        let arbiter_trace = NodeTrace::new();
        let arbiter_join = spawn_arbiter(
            shard_inputs,
            reg_rx,
            ctl_rx,
            cfg.placement,
            cfg.elastic.clone(),
            ArbiterShared {
                completed: completed.clone(),
                abandoned: abandoned.clone(),
                lifecycle: arbiter_lc.clone(),
                trace: arbiter_trace.clone(),
                wait: arbiter_wait.clone(),
                stats: stats.clone(),
            },
        );
        let pool = AccelPool {
            mode,
            batch: cfg.batch.max(1),
            registry,
            ctl: ctl_tx,
            arbiter_lc,
            arbiter_trace,
            arbiter_join: Some(arbiter_join),
            shards,
            outputs,
            completed,
            out_done: vec![false; nshards],
            done_count: 0,
            cursor: 0,
            pending: VecDeque::new(),
            eos_sent: false,
            collected: 0,
            wait: WaitCfg {
                gauge: None, // the drain runs on the caller's thread
                ..arbiter_wait
            },
            disconnect_grace: cfg.disconnect_grace,
            force_closed: false,
            abandoned,
            abandoned_seen: 0,
            arbiter_gauge,
            stats,
        };
        let handle = pool.handle();
        (pool, handle)
    }

    /// Open another client handle for the current cycle (equivalent to
    /// cloning an existing one). Panics after [`AccelPool::offload_eos`]
    /// — thaw into the next cycle first.
    pub fn handle(&self) -> AccelHandle<I> {
        assert!(
            !self.eos_sent,
            "AccelPool::handle() after offload_eos (thaw the next cycle first)"
        );
        AccelHandle::new(self.registry.clone(), self.batch)
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.outputs.len()
    }

    /// Shards currently receiving admissions: `shards()` on eager and
    /// non-autoscaled pools, the autoscaler's live count otherwise.
    pub fn live_shards(&self) -> usize {
        // ordering: stat — racy gauge read.
        self.stats.live.load(Ordering::Relaxed) as usize
    }

    /// Snapshot the pool's elasticity counters — steal/cancel/scale
    /// activity and the current backlog gauge. Cheap (a handful of
    /// relaxed loads) and callable at any time.
    pub fn stats(&self) -> PoolStats {
        let s = &self.stats;
        PoolStats {
            shards: self.outputs.len(),
            // ordering: stat — report-time reads of arbiter-written
            // cells; staleness is acceptable by design.
            live_shards: s.live.load(Ordering::Relaxed) as usize,
            steals: s.steals.load(Ordering::Relaxed),
            stolen_items: s.stolen_items.load(Ordering::Relaxed),
            cancelled_jobs: s.cancelled_jobs.load(Ordering::Relaxed),
            cancelled_items: s.cancelled_items.load(Ordering::Relaxed),
            scale_ups: s.scale_ups.load(Ordering::Relaxed),
            scale_downs: s.scale_downs.load(Ordering::Relaxed),
            backlog: s.backlog.load(Ordering::Relaxed),
        }
    }

    /// Pool-wide end-of-stream: after this, the cycle closes as soon as
    /// every client handle has finished (or been dropped). Idempotent
    /// within a cycle.
    pub fn offload_eos(&mut self) {
        if !self.eos_sent {
            let _ = self.ctl.send(Ctl::CloseCycle);
            self.eos_sent = true;
        }
    }

    /// Single-writer completion counter bump (no RMW: the pool is the
    /// only writer, the arbiter only reads).
    fn note_completed(&self, shard: usize) {
        let c = &self.completed[shard];
        // ordering: stat — single-writer counter feeding a load heuristic.
        c.store(c.load(Ordering::Relaxed) + 1, Ordering::Relaxed);
    }

    /// Pop one merged result if immediately available, polling the
    /// shards round-robin from the last productive one.
    #[must_use = "a popped result must be consumed (None may just mean not-ready-yet)"]
    pub fn load_result_nb(&mut self) -> Option<O> {
        if let Some((s, v)) = self.pending.pop_front() {
            self.note_completed(s);
            self.collected += 1;
            return Some(v);
        }
        let n = self.outputs.len();
        if self.done_count == n {
            return None;
        }
        for k in 0..n {
            let s = (self.cursor + k) % n;
            if self.out_done[s] {
                continue;
            }
            match self.outputs[s].try_recv() {
                Some(Msg::Task(v)) => {
                    self.cursor = s; // keep draining the hot shard
                    self.note_completed(s);
                    self.collected += 1;
                    return Some(v);
                }
                Some(Msg::Batch(vs)) => {
                    self.cursor = s;
                    // The emptied frame goes back to the shard's
                    // collector through the free lane.
                    let pending = &mut self.pending;
                    self.outputs[s]
                        .recycle_after(vs, |vs| pending.extend(vs.drain(..).map(|v| (s, v))));
                    if let Some((s2, v)) = self.pending.pop_front() {
                        self.note_completed(s2);
                        self.collected += 1;
                        return Some(v);
                    }
                }
                Some(Msg::Eos) => {
                    self.out_done[s] = true;
                    self.done_count += 1;
                }
                None => {
                    // A shard whose collector died without EOS must not
                    // wedge the merged drain.
                    if !self.outputs[s].peer_alive() && !self.outputs[s].has_next() {
                        self.out_done[s] = true;
                        self.done_count += 1;
                    }
                }
            }
        }
        None
    }

    /// Pop one merged result, blocking until one arrives or every
    /// shard's cycle output reached EOS (`None`). Idle waits use the
    /// shared [`Backoff`] escalation — and, under a `Park`-mode pool,
    /// park on any shard output's doorbell — so draining a quiet pool
    /// does not burn the caller's core.
    ///
    /// In the parking modes this is also where **leaked-handle
    /// recovery** runs: a cycle that is closed (`offload_eos` sent), produces
    /// nothing for [`field@PoolConfig::disconnect_grace`], and still has
    /// registered-but-unfinished lanes (the registration-epoch gap) is
    /// wedged by a handle that will never close — `mem::forget`, or a
    /// handle stranded in a poisoned mutex. The drain then force-closes
    /// the abandoned lanes (the arbiter forwards whatever they
    /// buffered) so the cycle terminates; [`AccelPool::wait_checked`]
    /// surfaces it as [`AccelError::Disconnected`].
    pub fn load_result(&mut self) -> Option<O> {
        let mut backoff = Backoff::new();
        let mut stalled: Option<Instant> = None;
        loop {
            if let Some(v) = self.load_result_nb() {
                return Some(v);
            }
            if self.done_count == self.outputs.len() {
                return None;
            }
            if self.wait.mode != WaitMode::Spin
                && self.eos_sent
                && !self.force_closed
                && self.registry.opened() > self.registry.finished()
                && stalled.get_or_insert_with(Instant::now).elapsed() >= self.disconnect_grace
            {
                let _ = self.ctl.send(Ctl::ForceClose);
                self.force_closed = true;
            }
            if self.wait.wants_park(&mut backoff) {
                let bells: Vec<&Doorbell> = self
                    .outputs
                    .iter()
                    .enumerate()
                    .filter(|(s, _)| !self.out_done[*s])
                    .map(|(_, rx)| rx.data_bell())
                    .collect();
                let (outputs, out_done) = (&self.outputs, &self.out_done);
                self.wait.park_any(&bells, || {
                    !outputs.iter().enumerate().any(|(s, rx)| {
                        !out_done[s] && (rx.has_next() || !rx.peer_alive())
                    })
                });
            } else {
                backoff.snooze();
            }
        }
    }

    /// Block until every thread of every shard (and the arbiter) is
    /// frozen. Requires `run_then_freeze`.
    pub fn wait_freezing(&self) {
        for sh in &self.shards {
            sh.lifecycle.wait_freezing();
        }
        self.arbiter_lc.wait_freezing();
    }

    /// Wake the frozen pool for another burst; resets per-cycle state.
    pub fn thaw(&mut self) {
        assert_eq!(
            self.mode,
            RunMode::RunThenFreeze,
            "thaw on a run-to-end pool"
        );
        debug_assert!(self.eos_sent, "thaw before offload_eos");
        debug_assert!(
            self.pending.is_empty() && self.done_count == self.outputs.len(),
            "thaw before draining the merged output (results would bleed \
             into the next cycle)"
        );
        self.arbiter_lc.thaw();
        for sh in &self.shards {
            sh.lifecycle.thaw();
        }
        self.eos_sent = false;
        for d in self.out_done.iter_mut() {
            *d = false;
        }
        self.done_count = 0;
        self.collected = 0;
        self.force_closed = false;
        self.abandoned_seen = self.abandoned.load(Ordering::SeqCst);
    }

    /// True once any shard raised its poison flag (see
    /// [`crate::accel::Accel::poisoned`]).
    pub fn poisoned(&self) -> bool {
        self.shards.iter().any(|s| s.poisoned())
    }

    /// Total threads run by the pool (arbiter + all shard threads).
    pub fn threads(&self) -> usize {
        1 + self
            .shards
            .iter()
            .map(|s| s.lifecycle.threads())
            .sum::<usize>()
    }

    /// Pool threads currently parked on stream doorbells: the arbiter
    /// plus every shard thread (a racy snapshot; nonzero only under an
    /// `Adaptive`/`Park` pool). This is the observable behind the
    /// idle-shard elasticity claim: an idle `Park`-mode pool reaches
    /// `parked_threads() == threads()`.
    pub fn parked_threads(&self) -> usize {
        self.arbiter_gauge.parked_now()
            + self
                .shards
                .iter()
                .map(|s| s.park_gauge.parked_now())
                .sum::<usize>()
    }

    /// Client lanes the arbiter force-closed as abandoned in the
    /// current cycle (see [`AccelPool::load_result`]).
    pub fn abandoned_lanes(&self) -> u64 {
        self.abandoned.load(Ordering::SeqCst) - self.abandoned_seen
    }

    /// Merged trace snapshot: the arbiter plus every shard's nodes,
    /// shard rows prefixed `s<i>/`.
    pub fn trace_report(&self) -> TraceReport {
        let mut rows = vec![self.arbiter_trace.snapshot("arbiter")];
        for (i, sh) in self.shards.iter().enumerate() {
            merge_shard_rows(&mut rows, i, sh.trace_report());
        }
        TraceReport { rows }
    }

    /// Final join: sends the pool-wide EOS, drains remaining results,
    /// tells frozen threads to exit and joins them all. All client
    /// handles must already be finished (or dropped) — the drain waits
    /// for their lanes to close (in the parking modes, a lane wedged by a
    /// *leaked* handle is force-closed after
    /// [`field@PoolConfig::disconnect_grace`]; use [`AccelPool::wait_checked`]
    /// to observe that as an error).
    pub fn wait(mut self) -> TraceReport {
        self.finish().0
    }

    /// Like [`AccelPool::wait`], but surfaces leaked-handle recovery:
    /// `Err(AccelError::Disconnected)` if any client lane of the final
    /// cycle had to be force-closed because its handle never ran its
    /// close path (`mem::forget`, a handle stranded in a poisoned
    /// mutex). The pool is fully drained and joined either way.
    pub fn wait_checked(mut self) -> Result<TraceReport, AccelError> {
        let (report, err) = self.finish();
        match err {
            None => Ok(report),
            Some(e) => Err(e),
        }
    }

    fn finish(&mut self) -> (TraceReport, Option<AccelError>) {
        self.offload_eos();
        while self.load_result().is_some() {}
        let err = if self.abandoned_lanes() > 0 {
            Some(AccelError::Disconnected)
        } else {
            None
        };
        self.arbiter_lc.request_exit();
        for sh in &self.shards {
            sh.lifecycle.request_exit();
        }
        if let Some(j) = self.arbiter_join.take() {
            let _ = j.join();
        }
        let mut rows = vec![self.arbiter_trace.snapshot("arbiter")];
        for (i, sh) in self.shards.drain(..).enumerate() {
            merge_shard_rows(&mut rows, i, sh.join());
        }
        (TraceReport { rows }, err)
    }
}

impl<I: Send + 'static, O: Send + 'static> Drop for AccelPool<I, O> {
    /// A pool dropped without [`AccelPool::wait`] must not leak
    /// OS-suspended threads: in freeze mode the shards would otherwise
    /// park forever after the arbiter's pool-dropped EOS. Telling every
    /// lifecycle to exit lets them run out instead (idempotent after
    /// `wait()`, which already drained `shards`).
    fn drop(&mut self) {
        self.arbiter_lc.request_exit();
        for sh in &self.shards {
            sh.lifecycle.request_exit();
        }
    }
}

/// Choose a shard for the next task/batch.
#[inline]
fn pick_shard(
    placement: Placement,
    rr: &mut usize,
    dispatched: &[u64],
    completed: &[AtomicU64],
) -> usize {
    let n = dispatched.len();
    match placement {
        // Topology placement affects where shard *threads* live, not
        // where tasks go — dispatch rotates exactly like RoundRobin.
        Placement::RoundRobin | Placement::Topology => {
            let s = *rr;
            *rr = (*rr + 1) % n;
            s
        }
        Placement::LeastLoaded => {
            let mut best = 0usize;
            let mut best_load = u64::MAX;
            for (i, d) in dispatched.iter().enumerate() {
                // `completed` counts *results* while `dispatched` counts
                // *tasks*; workers are allowed to emit 0 or ≥2 results
                // per task (arrival-ordered farms), so the delta is a
                // load heuristic, not an invariant — saturate it.
                // ordering: stat — racy heuristic read; a stale count
                // only skews placement, never correctness.
                let load = d.saturating_sub(completed[i].load(Ordering::Relaxed));
                if load < best_load {
                    best_load = load;
                    best = i;
                }
            }
            best
        }
    }
}

/// The shared state handed to the pool's input arbiter (bundled so the
/// spawn signature stays readable).
struct ArbiterShared {
    completed: Arc<Vec<AtomicU64>>,
    /// Client lanes force-closed as abandoned (leaked handles).
    abandoned: Arc<AtomicU64>,
    lifecycle: Arc<Lifecycle>,
    trace: Arc<NodeTrace>,
    wait: WaitCfg,
    stats: Arc<StatsCells>,
}

/// One registered client lane, as the arbiter sees it: the frame
/// stream, the give side of the client's batch-buffer free lane, and
/// the lane's sticky home shard (elastic admission).
struct Lane<I: Send + 'static> {
    rx: Receiver<Job<I>>,
    ret: BatchReturner<I>,
    open: bool,
    home: usize,
}

/// A frame admitted into a shard's elastic backlog, waiting for window
/// room: admission sequence (for the aging rule), owning lane (for
/// buffer return), cancel handle, and the task body.
struct Backlogged<I> {
    seq: u64,
    lane: usize,
    ctl: Option<Arc<JobCtl>>,
    body: JobBody<I>,
}

/// One shard's backlog: a FIFO per priority class.
type ShardBacklog<I> = [VecDeque<Backlogged<I>>; PRIORITY_LANES];

fn backlog_jobs<I>(b: &ShardBacklog<I>) -> u64 {
    b.iter().map(|q| q.len() as u64).sum()
}

/// Serve a shard's own backlog: priority order (High → Low), except
/// that an aging pop takes the globally oldest front so no class
/// starves.
fn pop_backlog<I>(b: &mut ShardBacklog<I>, aging: bool) -> Option<Backlogged<I>> {
    if aging {
        let lane = (0..PRIORITY_LANES)
            .filter(|&l| !b[l].is_empty())
            .min_by_key(|&l| b[l].front().map_or(u64::MAX, |e| e.seq))?;
        return b[lane].pop_front();
    }
    b.iter_mut().find_map(|q| q.pop_front())
}

/// Steal from a sibling: the **tail** of its **lowest**-priority
/// non-empty lane — the frame the victim would serve last, so stealing
/// never reorders what the victim's own clients observe next.
fn steal_tail<I>(b: &mut ShardBacklog<I>) -> Option<Backlogged<I>> {
    b.iter_mut().rev().find_map(|q| q.pop_back())
}

/// Items dispatched to shard `s` and not yet seen back by the pool.
/// `completed` counts *results* while `dispatched` counts *tasks*;
/// workers may emit 0 or ≥2 results per task, so this is a load
/// heuristic, not an invariant — saturate it.
#[inline]
fn inflight(s: usize, dispatched: &[u64], completed: &[AtomicU64]) -> u64 {
    // ordering: stat — racy heuristic read (see doc comment).
    dispatched[s].saturating_sub(completed[s].load(Ordering::Relaxed))
}

/// Send one dispatch-ready frame into shard `s`. Returns `false` if the
/// job's cancel won the race (the frame is dropped and accounted,
/// nothing reaches the shard).
fn dispatch_frame<I: Send + 'static>(
    frame: Backlogged<I>,
    s: usize,
    lanes: &mut [Lane<I>],
    shard_inputs: &mut [Sender<I>],
    dispatched: &mut [u64],
    trace: &NodeTrace,
    stats: &StatsCells,
) -> bool {
    let Backlogged { lane, ctl, body, .. } = frame;
    if let Some(ctl) = ctl {
        if !ctl.try_start() {
            stats.note_cancel(body, &mut lanes[lane].ret);
            return false;
        }
    }
    let t0 = Instant::now();
    match body {
        JobBody::One(t) => {
            let _ = shard_inputs[s].send(t);
            dispatched[s] += 1;
            trace.on_task(t0.elapsed().as_nanos() as u64);
            trace.on_emit(1);
        }
        JobBody::Many(mut ts) => {
            // Re-frame instead of forwarding the client's Vec: the run
            // moves into a buffer recycled on the shard link (returned
            // by that shard's emitter) and the client's buffer goes
            // back through its own lane's free lane — both return paths
            // stay SPSC and the arbiter allocates nothing after warmup.
            let k = ts.len() as u64;
            let mut run = shard_inputs[s].take_buf();
            run.append(&mut ts);
            lanes[lane].ret.give(ts);
            let _ = shard_inputs[s].send_batch(run);
            dispatched[s] += k;
            trace.on_tasks(k, t0.elapsed().as_nanos() as u64);
            trace.on_emit(k);
        }
    }
    true
}

/// Relief valve for the elastic window (`dispatched - completed` is a
/// heuristic): if the backlog is non-empty but no dispatch and no
/// completion happened for this long, bypass the window once so a
/// workload whose workers emit ≠ 1 result per task can never wedge the
/// pool.
const STALL_BYPASS: Duration = Duration::from_millis(25);

/// Register a freshly-announced client lane. The home shard is
/// lane-sticky: `lane index % live` — under skew this is what makes a
/// hot client's overload *visible on one shard* so stealing (not
/// placement averaging) heals it; eager pools ignore it.
fn admit_lane<I: Send + 'static>(
    nl: NewLane<I>,
    lanes: &mut Vec<Lane<I>>,
    open: &mut usize,
    live: usize,
) {
    let home = lanes.len() % live.max(1);
    lanes.push(Lane {
        rx: nl.rx,
        ret: nl.ret,
        open: true,
        home,
    });
    *open += 1;
}

/// Drain pending registrations — polled AFTER the lanes: popping a
/// lane's Eos happens-after that client enqueued any clone
/// registration, so a close can never outrun the clone it spawned.
fn drain_registrations<I: Send + 'static>(
    reg_rx: &mut Receiver<NewLane<I>>,
    lanes: &mut Vec<Lane<I>>,
    open: &mut usize,
    live: usize,
    progressed: &mut bool,
) {
    while let Some(m) = reg_rx.try_recv() {
        match m {
            Msg::Task(nl) => {
                *progressed = true;
                admit_lane(nl, lanes, open, live);
            }
            Msg::Batch(ls) => {
                *progressed = true;
                for nl in ls {
                    admit_lane(nl, lanes, open, live);
                }
            }
            Msg::Eos => {}
        }
    }
}

/// The pool's input arbiter: merges every client lane into the shard
/// inputs (SPMC over SPSC lanes, §2.3 — no locks, no RMW on the data
/// path) and applies the placement policy per frame (a batch stays
/// whole so its single-synchronization economy survives into the shard,
/// whose emitter unpacks it for scheduling). Two dispatch disciplines:
/// the legacy **eager** cycle (forward immediately — `elastic: None`)
/// and the **elastic** cycle (windowed per-shard priority backlogs with
/// stealing, cancellation and autoscale). Idle waits ride the shared
/// spin→yield→park escalation, parking on any lane/control doorbell;
/// any client offload rings the arbiter awake, which is what wakes a
/// wholesale-parked idle pool on the next dispatch.
fn spawn_arbiter<I: Send + 'static>(
    mut shard_inputs: Vec<Sender<I>>,
    mut reg_rx: Receiver<NewLane<I>>,
    mut ctl_rx: Receiver<Ctl>,
    placement: Placement,
    elastic: Option<ElasticConfig>,
    shared: ArbiterShared,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("ff-pool-arbiter".into())
        .spawn(move || {
            let nshards = shard_inputs.len();
            let mut rr = 0usize;
            // Cumulative per-shard dispatch counts: arbiter-local plain
            // integers (single writer — this thread), paired with the
            // pool-side `completed` atomics for in-flight load.
            let mut dispatched = vec![0u64; nshards];
            loop {
                // ---- one run cycle -----------------------------------
                let exit_after_cycle = match &elastic {
                    None => eager_cycle(
                        &mut shard_inputs,
                        &mut reg_rx,
                        &mut ctl_rx,
                        placement,
                        &mut rr,
                        &mut dispatched,
                        &shared,
                    ),
                    Some(ecfg) => elastic_cycle(
                        ecfg,
                        &mut shard_inputs,
                        &mut reg_rx,
                        &mut ctl_rx,
                        placement,
                        &mut dispatched,
                        &shared,
                    ),
                };
                // Propagate EOS into every shard.
                for s in shard_inputs.iter_mut() {
                    let _ = s.send_eos();
                }
                // Publish the cycle's buffer-pool activity so the
                // fresh-allocation plateau is visible in TraceReport.
                let (mut fresh, mut reused) = (0u64, 0u64);
                for s in shard_inputs.iter_mut() {
                    let (f, r) = s.take_alloc_stats();
                    fresh += f;
                    reused += r;
                }
                shared.trace.on_alloc(fresh, reused);
                shared.trace.on_cycle();
                if exit_after_cycle || !shared.lifecycle.cycle_end() {
                    break;
                }
            }
        })
        .expect("spawn pool arbiter")
}

/// One run cycle of the legacy **eager** arbiter: every admitted frame
/// forwards to a shard the moment it is drained from its lane — the
/// exact pre-elastic pool behavior, with [`Job`] envelopes unpacked
/// (and tracked jobs claimed, so `JobToken::cancel` still means
/// never-submitted when it wins) at the moment of forwarding. Returns
/// `true` if the pool was dropped and the arbiter must exit.
fn eager_cycle<I: Send + 'static>(
    shard_inputs: &mut [Sender<I>],
    reg_rx: &mut Receiver<NewLane<I>>,
    ctl_rx: &mut Receiver<Ctl>,
    placement: Placement,
    rr: &mut usize,
    dispatched: &mut [u64],
    shared: &ArbiterShared,
) -> bool {
    let completed = &*shared.completed;
    let mut lanes: Vec<Lane<I>> = Vec::new();
    let mut open = 0usize;
    let mut closing = false;
    let mut force_close = false;
    let mut exit_after_cycle = false;
    let mut backoff = Backoff::new();
    loop {
        let mut progressed = false;
        // 1. pool control
        while let Some(m) = ctl_rx.try_recv() {
            match m {
                Msg::Task(Ctl::CloseCycle) | Msg::Eos => {
                    progressed = true;
                    closing = true;
                }
                Msg::Task(Ctl::ForceClose) => {
                    progressed = true;
                    closing = true;
                    force_close = true;
                }
                Msg::Batch(_) => unreachable!("control is never batched"),
            }
        }
        if !ctl_rx.peer_alive() && !ctl_rx.has_next() {
            // Pool dropped without wait(): finish the cycle with what
            // we have and exit.
            closing = true;
            exit_after_cycle = true;
        }
        // 2. client lanes: burst-drain each open lane
        for li in 0..lanes.len() {
            if !lanes[li].open {
                continue;
            }
            for _ in 0..LANE_BURST {
                match lanes[li].rx.try_recv() {
                    Some(Msg::Task(job)) => {
                        progressed = true;
                        // Eager pools dispatch immediately — there is no
                        // deferred work for `prio` to order.
                        let Job { ctl, body, .. } = job;
                        if let Some(ctl) = ctl {
                            if !ctl.try_start() {
                                shared.stats.note_cancel(body, &mut lanes[li].ret);
                                continue;
                            }
                        }
                        let t0 = Instant::now();
                        match body {
                            JobBody::One(t) => {
                                let s = pick_shard(placement, rr, dispatched, completed);
                                let _ = shard_inputs[s].send(t);
                                dispatched[s] += 1;
                                shared.trace.on_task(t0.elapsed().as_nanos() as u64);
                                shared.trace.on_emit(1);
                            }
                            JobBody::Many(mut ts) => {
                                let k = ts.len() as u64;
                                let s = pick_shard(placement, rr, dispatched, completed);
                                // Re-frame instead of forwarding the
                                // client's Vec: the run moves into a
                                // buffer recycled on the shard link
                                // (returned by that shard's emitter)
                                // and the client's buffer goes back
                                // through its own lane — both return
                                // paths stay SPSC and the arbiter
                                // allocates nothing after warmup.
                                let mut run = shard_inputs[s].take_buf();
                                run.append(&mut ts);
                                lanes[li].ret.give(ts);
                                let _ = shard_inputs[s].send_batch(run);
                                dispatched[s] += k;
                                shared.trace.on_tasks(k, t0.elapsed().as_nanos() as u64);
                                shared.trace.on_emit(k);
                            }
                        }
                    }
                    Some(Msg::Batch(_)) => unreachable!("lanes carry Job frames, never Batch"),
                    Some(Msg::Eos) => {
                        progressed = true;
                        lanes[li].open = false;
                        open -= 1;
                        break;
                    }
                    None => {
                        // A client thread that died without closing
                        // (e.g. mem::forget) must not wedge the cycle.
                        if !lanes[li].rx.peer_alive() && !lanes[li].rx.has_next() {
                            progressed = true;
                            lanes[li].open = false;
                            open -= 1;
                        }
                        break;
                    }
                }
            }
        }
        // 3. registrations
        drain_registrations(reg_rx, &mut lanes, &mut open, shard_inputs.len(), &mut progressed);
        // 4. leaked-handle recovery: after a ForceClose, close every
        // drained lane unconditionally (frames still buffered were
        // forwarded by step 2 above; the lane's handle will never send
        // EOS).
        if force_close {
            for l in lanes.iter_mut() {
                if l.open && !l.rx.has_next() {
                    l.open = false;
                    open -= 1;
                    shared.abandoned.fetch_add(1, Ordering::SeqCst);
                    progressed = true;
                }
            }
        }
        // 5. cycle completion: pool closed + all lanes done.
        if closing && open == 0 {
            return exit_after_cycle;
        }
        if progressed {
            backoff.reset();
        } else if shared.wait.wants_park(&mut backoff) {
            // Everything idle: park until a client offload, a
            // registration, or pool control rings.
            let mut bells: Vec<&Doorbell> = Vec::with_capacity(lanes.len() + 2);
            bells.push(ctl_rx.data_bell());
            bells.push(reg_rx.data_bell());
            bells.extend(
                lanes
                    .iter()
                    .filter(|l| l.open)
                    .map(|l| l.rx.data_bell()),
            );
            shared.wait.park_any(&bells, || {
                ctl_rx.peer_alive()
                    && !ctl_rx.has_next()
                    && !reg_rx.has_next()
                    && !lanes
                        .iter()
                        .any(|l| l.open && (l.rx.has_next() || !l.rx.peer_alive()))
            });
        } else {
            backoff.snooze();
        }
    }
}

/// One run cycle of the **elastic** arbiter (ISSUE 9 tentpole). Every
/// admitted frame lands in its shard's backlog (one FIFO per priority
/// class); dispatch is *windowed* — a shard receives its next frame
/// only while its in-flight items sit under [`ElasticConfig::window`].
/// The deferral enables everything else:
///
/// * **steal** — a live shard with window room and an empty backlog
///   pulls the tail of the most-backlogged sibling's lowest-priority
///   lane, whole frames only;
/// * **cancel** — a tracked job is claimed (`try_start`) at dispatch;
///   if its token's cancel won, the frame is dropped and accounted
///   (cancel ≡ never-submitted);
/// * **priorities + aging** — High before Normal before Low, except
///   every `age_every`-th dispatch serves the shard's oldest frame, so
///   no class starves;
/// * **autoscale** — sustained backlog grows the live set (dwell
///   hysteresis both ways; shrink requires a fully idle pool and a
///   longer dwell, so the pool never flaps).
///
/// Returns `true` if the pool was dropped and the arbiter must exit.
fn elastic_cycle<I: Send + 'static>(
    ecfg: &ElasticConfig,
    shard_inputs: &mut [Sender<I>],
    reg_rx: &mut Receiver<NewLane<I>>,
    ctl_rx: &mut Receiver<Ctl>,
    placement: Placement,
    dispatched: &mut [u64],
    shared: &ArbiterShared,
) -> bool {
    let completed = &*shared.completed;
    let stats = &*shared.stats;
    let nshards = shard_inputs.len();
    let min_live = ecfg.min_live.clamp(1, nshards);
    let mut live = if ecfg.autoscale { min_live } else { nshards };
    StatsCells::put(&stats.live, live as u64);
    let mut lanes: Vec<Lane<I>> = Vec::new();
    let mut open = 0usize;
    let mut closing = false;
    let mut force_close = false;
    let mut exit_after_cycle = false;
    let mut backlog: Vec<ShardBacklog<I>> = (0..nshards)
        .map(|_| std::array::from_fn(|_| VecDeque::new()))
        .collect();
    let mut total_backlog = 0u64; // jobs across all shard backlogs
    let mut seq = 0u64; // admission order, drives the aging rule
    let mut served = vec![0u64; nshards]; // dispatches per shard (aging)
    let mut grow_since: Option<Instant> = None;
    let mut shrink_since: Option<Instant> = None;
    let mut stall: Option<(Instant, u64)> = None;
    let mut backoff = Backoff::new();
    loop {
        let mut progressed = false;
        // 1. pool control
        while let Some(m) = ctl_rx.try_recv() {
            match m {
                Msg::Task(Ctl::CloseCycle) | Msg::Eos => {
                    progressed = true;
                    closing = true;
                }
                Msg::Task(Ctl::ForceClose) => {
                    progressed = true;
                    closing = true;
                    force_close = true;
                }
                Msg::Batch(_) => unreachable!("control is never batched"),
            }
        }
        if !ctl_rx.peer_alive() && !ctl_rx.has_next() {
            closing = true;
            exit_after_cycle = true;
        }
        // 2. admission: burst-drain each open lane into its shard's
        // backlog. RoundRobin/Topology admit lane-sticky (the lane's
        // home shard — skew stays visible, stealing heals it);
        // LeastLoaded keeps per-frame load-based admission over the
        // live set.
        for li in 0..lanes.len() {
            if !lanes[li].open {
                continue;
            }
            for _ in 0..LANE_BURST {
                match lanes[li].rx.try_recv() {
                    Some(Msg::Task(job)) => {
                        progressed = true;
                        let s = match placement {
                            Placement::RoundRobin | Placement::Topology => {
                                if lanes[li].home >= live {
                                    lanes[li].home %= live;
                                }
                                lanes[li].home
                            }
                            Placement::LeastLoaded => (0..live)
                                .min_by_key(|&s| {
                                    inflight(s, dispatched, completed) + backlog_jobs(&backlog[s])
                                })
                                .unwrap_or(0),
                        };
                        backlog[s][job.prio.lane()].push_back(Backlogged {
                            seq,
                            lane: li,
                            ctl: job.ctl,
                            body: job.body,
                        });
                        seq += 1;
                        total_backlog += 1;
                    }
                    Some(Msg::Batch(_)) => unreachable!("lanes carry Job frames, never Batch"),
                    Some(Msg::Eos) => {
                        progressed = true;
                        lanes[li].open = false;
                        open -= 1;
                        break;
                    }
                    None => {
                        if !lanes[li].rx.peer_alive() && !lanes[li].rx.has_next() {
                            progressed = true;
                            lanes[li].open = false;
                            open -= 1;
                        }
                        break;
                    }
                }
            }
        }
        // 3. registrations
        drain_registrations(reg_rx, &mut lanes, &mut open, live, &mut progressed);
        // 4. windowed dispatch: serve each live shard from its own
        // backlog while its in-flight window has room.
        let mut dispatched_this_round = false;
        for s in 0..live {
            while total_backlog > 0 && inflight(s, dispatched, completed) < ecfg.window {
                let aging = ecfg.age_every > 0 && (served[s] + 1) % ecfg.age_every == 0;
                let Some(frame) = pop_backlog(&mut backlog[s], aging) else {
                    break;
                };
                total_backlog -= 1;
                progressed = true;
                if dispatch_frame(
                    frame,
                    s,
                    &mut lanes,
                    shard_inputs,
                    dispatched,
                    &shared.trace,
                    stats,
                ) {
                    served[s] += 1;
                    dispatched_this_round = true;
                }
            }
        }
        // 5. steal: an idle live shard (window room, empty backlog)
        // pulls whole frames from the tail of the most-backlogged
        // sibling's lowest-priority lane and runs them immediately.
        if ecfg.steal && total_backlog > 0 {
            for s in 0..live {
                if backlog_jobs(&backlog[s]) > 0 {
                    continue;
                }
                while total_backlog > 0 && inflight(s, dispatched, completed) < ecfg.window {
                    let victim = (0..live)
                        .filter(|&v| v != s)
                        .max_by_key(|&v| backlog_jobs(&backlog[v]))
                        .filter(|&v| backlog_jobs(&backlog[v]) > 0);
                    let Some(v) = victim else { break };
                    let Some(frame) = steal_tail(&mut backlog[v]) else {
                        break;
                    };
                    total_backlog -= 1;
                    progressed = true;
                    StatsCells::bump(&stats.steals, 1);
                    StatsCells::bump(&stats.stolen_items, frame.body.len() as u64);
                    if dispatch_frame(
                        frame,
                        s,
                        &mut lanes,
                        shard_inputs,
                        dispatched,
                        &shared.trace,
                        stats,
                    ) {
                        served[s] += 1;
                        dispatched_this_round = true;
                    }
                }
            }
        }
        // 6. stall relief: the window rests on `dispatched - completed`,
        // which assumes roughly one result per task. A workload whose
        // workers emit 0 results can pin every window "full" forever —
        // if the backlog is non-empty and neither a dispatch nor a
        // completion happened for STALL_BYPASS, push one frame through
        // regardless of the window.
        if total_backlog > 0 && !dispatched_this_round {
            // ordering: stat — stall detection over racy counters; the
            // bypass only needs eventual progress, not precision.
            let done: u64 = completed.iter().map(|c| c.load(Ordering::Relaxed)).sum();
            match stall {
                Some((t0, seen)) if seen == done => {
                    if t0.elapsed() >= STALL_BYPASS {
                        'bypass: for s in 0..live {
                            if let Some(frame) = pop_backlog(&mut backlog[s], false) {
                                total_backlog -= 1;
                                progressed = true;
                                if dispatch_frame(
                                    frame,
                                    s,
                                    &mut lanes,
                                    shard_inputs,
                                    dispatched,
                                    &shared.trace,
                                    stats,
                                ) {
                                    served[s] += 1;
                                }
                                break 'bypass;
                            }
                        }
                        stall = None;
                    }
                }
                _ => stall = Some((Instant::now(), done)),
            }
        } else {
            stall = None;
        }
        // 7. autoscale with dwell hysteresis both ways.
        if ecfg.autoscale {
            if total_backlog > 0 && live < nshards {
                let since = *grow_since.get_or_insert_with(Instant::now);
                if since.elapsed() >= ecfg.grow_dwell {
                    live += 1;
                    StatsCells::bump(&stats.scale_ups, 1);
                    StatsCells::put(&stats.live, live as u64);
                    grow_since = None; // re-arm: each step earns its own dwell
                    progressed = true;
                }
            } else {
                grow_since = None;
            }
            let idle = total_backlog == 0
                && (0..live).all(|s| inflight(s, dispatched, completed) == 0);
            if idle && live > min_live {
                let since = *shrink_since.get_or_insert_with(Instant::now);
                if since.elapsed() >= ecfg.shrink_dwell {
                    live -= 1;
                    StatsCells::bump(&stats.scale_downs, 1);
                    StatsCells::put(&stats.live, live as u64);
                    // Re-home lanes stranded on the retired shard.
                    for l in lanes.iter_mut() {
                        if l.home >= live {
                            l.home %= live;
                        }
                    }
                    shrink_since = None;
                    progressed = true;
                }
            } else {
                shrink_since = None;
            }
        }
        // 8. leaked-handle recovery (as in the eager cycle).
        if force_close {
            for l in lanes.iter_mut() {
                if l.open && !l.rx.has_next() {
                    l.open = false;
                    open -= 1;
                    shared.abandoned.fetch_add(1, Ordering::SeqCst);
                    progressed = true;
                }
            }
        }
        StatsCells::put(&stats.backlog, total_backlog);
        // 9. cycle completion: pool closed, all lanes done, nothing
        // still held back.
        if closing && open == 0 && total_backlog == 0 {
            return exit_after_cycle;
        }
        if progressed {
            backoff.reset();
        } else if total_backlog == 0 && shared.wait.wants_park(&mut backoff) {
            // Park only with an empty backlog: with frames held back,
            // progress comes from shard *completions* (no doorbell), so
            // the arbiter stays on the spin→yield escalation — which is
            // also what keeps the STALL_BYPASS clock honest.
            let mut bells: Vec<&Doorbell> = Vec::with_capacity(lanes.len() + 2);
            bells.push(ctl_rx.data_bell());
            bells.push(reg_rx.data_bell());
            bells.extend(
                lanes
                    .iter()
                    .filter(|l| l.open)
                    .map(|l| l.rx.data_bell()),
            );
            shared.wait.park_any(&bells, || {
                ctl_rx.peer_alive()
                    && !ctl_rx.has_next()
                    && !reg_rx.has_next()
                    && !lanes
                        .iter()
                        .any(|l| l.open && (l.rx.has_next() || !l.rx.peer_alive()))
            });
        } else {
            backoff.snooze();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::farm::{CollectorOrdering, SchedPolicy};
    use crate::node::node_fn;

    fn square_pool(shards: usize, batch: usize) -> (AccelPool<u64, u64>, AccelHandle<u64>) {
        AccelPool::run(
            PoolConfig::default()
                .shards(shards)
                .batch(batch)
                .workers_per_shard(2),
            |_s, _w| node_fn(|x: u64| x * x),
        )
    }

    #[test]
    fn single_client_pool_roundtrip() {
        let (mut pool, mut h) = square_pool(2, 1);
        for i in 0..500u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut got = vec![];
        while let Some(v) = pool.load_result() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, (0..500u64).map(|i| i * i).collect::<Vec<_>>());
        assert_eq!(pool.collected, 500);
        let report = pool.wait();
        let arb = report.rows.iter().find(|r| r.name == "arbiter").unwrap();
        assert_eq!(arb.tasks, 500);
    }

    #[test]
    fn four_clients_two_shards_exact_result_set() {
        // The acceptance shape: ≥4 handle clones on their own threads,
        // a 2-shard pool, exactly the sequential result set out.
        let (mut pool, root) = square_pool(2, 8);
        let clients = 4u64;
        let per_client = 1_000u64;
        let joins: Vec<_> = (0..clients)
            .map(|c| {
                let mut h = root.clone();
                std::thread::spawn(move || {
                    for i in 0..per_client {
                        h.offload(c * per_client + i).unwrap();
                    }
                    h.finish().unwrap();
                })
            })
            .collect();
        drop(root); // closes the root lane
        pool.offload_eos();
        let mut got = vec![];
        while let Some(v) = pool.load_result() {
            got.push(v);
        }
        for j in joins {
            j.join().unwrap();
        }
        got.sort_unstable();
        let mut expect: Vec<u64> = (0..clients * per_client).map(|i| i * i).collect();
        expect.sort_unstable();
        assert_eq!(got, expect);
        pool.wait();
    }

    #[test]
    fn least_loaded_placement_conserves_tasks() {
        let (mut pool, mut h) = AccelPool::run(
            PoolConfig::default()
                .shards(3)
                .placement(Placement::LeastLoaded)
                .workers_per_shard(1),
            |_s, _w| node_fn(|x: u64| x + 1),
        );
        for i in 0..2_000u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut count = 0u64;
        let mut sum = 0u64;
        while let Some(v) = pool.load_result() {
            count += 1;
            sum += v;
        }
        assert_eq!(count, 2_000);
        assert_eq!(sum, (1..=2_000u64).sum::<u64>());
        // Every shard should have been exercised.
        let report = pool.wait();
        for s in 0..3 {
            let emitter = report
                .rows
                .iter()
                .find(|r| r.name == format!("s{s}/emitter"))
                .unwrap();
            assert!(emitter.tasks > 0, "shard {s} never used");
        }
    }

    #[test]
    fn pool_freeze_thaw_bursts() {
        let (mut pool, first) = AccelPool::run_then_freeze(
            PoolConfig::default().shards(2).workers_per_shard(2),
            |_s, _w| node_fn(|x: u64| x + 1),
        );
        let mut next_handle = Some(first);
        for burst in 0..4u64 {
            let mut h = next_handle.take().unwrap();
            for i in 0..300u64 {
                h.offload(burst * 1_000 + i).unwrap();
            }
            h.finish().unwrap();
            pool.offload_eos();
            let mut sum = 0u64;
            let mut count = 0u64;
            while let Some(v) = pool.load_result() {
                sum += v;
                count += 1;
            }
            assert_eq!(count, 300, "burst {burst}");
            assert_eq!(sum, (0..300u64).map(|i| burst * 1_000 + i + 1).sum::<u64>());
            pool.wait_freezing();
            pool.thaw();
            next_handle = Some(pool.handle());
        }
        // Close the final (unused) cycle and join.
        next_handle.take().unwrap().finish().unwrap();
        pool.wait();
    }

    #[test]
    fn batched_offload_matches_per_item_per_shard_order() {
        // One shard + ordered collectors: per-client FIFO survives
        // coalescing end-to-end.
        let (mut pool, mut h) = AccelPool::run(
            PoolConfig::default()
                .shards(1)
                .batch(16)
                .farm(FarmConfig::default().workers(4).ordered()),
            |_s, _w| node_fn(|x: u64| x),
        );
        for i in 0..1_000u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut expect = 0u64;
        while let Some(v) = pool.load_result() {
            assert_eq!(v, expect);
            expect += 1;
        }
        assert_eq!(expect, 1_000);
        assert_eq!(
            pool.trace_report()
                .rows
                .iter()
                .find(|r| r.name == "s0/emitter")
                .unwrap()
                .tasks,
            1_000
        );
        pool.wait();
    }

    #[test]
    fn handle_after_eos_panics() {
        let (mut pool, h) = square_pool(1, 1);
        h.finish().unwrap();
        pool.offload_eos();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.handle()));
        assert!(r.is_err(), "handle() after offload_eos must panic");
        while pool.load_result().is_some() {}
        pool.wait();
    }

    #[test]
    fn empty_cycle_terminates() {
        let (mut pool, h) = square_pool(2, 4);
        drop(h);
        pool.offload_eos();
        assert!(pool.load_result().is_none());
        pool.wait();
    }

    #[test]
    fn pool_of_pipeline_shards_exactly_once() {
        // The api_redesign acceptance shape: every shard is a pipeline
        // (seq → farm), launched through the same pool plumbing.
        use crate::skeleton::seq_fn;
        let (mut pool, root) = AccelPool::run_skeleton(
            PoolConfig::default().shards(2).batch(4),
            |_shard| {
                seq_fn(|x: u64| x + 1).then(farm(
                    FarmConfig::default().workers(2).ordered(),
                    |_| seq_fn(|x: u64| x * 3),
                ))
            },
        );
        let clients = 3u64;
        let per_client = 500u64;
        let joins: Vec<_> = (0..clients)
            .map(|c| {
                let mut h = root.clone();
                std::thread::spawn(move || {
                    for i in 0..per_client {
                        h.offload(c * per_client + i).unwrap();
                    }
                    h.finish().unwrap();
                })
            })
            .collect();
        drop(root);
        pool.offload_eos();
        let total = clients * per_client;
        let mut seen = vec![false; total as usize];
        while let Some(v) = pool.load_result() {
            let orig = (v / 3) - 1;
            assert_eq!((orig + 1) * 3, v, "value not of pipeline shape: {v}");
            assert!(!seen[orig as usize], "duplicate {orig}");
            seen[orig as usize] = true;
        }
        for j in joins {
            j.join().unwrap();
        }
        assert!(seen.iter().all(|&s| s), "lost tasks");
        // Shard trace rows carry the pipeline's stage names.
        let report = pool.wait();
        assert!(report
            .rows
            .iter()
            .any(|r| r.name.starts_with("s0/stage-") || r.name.starts_with("s1/stage-")));
    }

    #[test]
    fn config_run_skeleton_sugar() {
        use crate::skeleton::seq_fn;
        let (mut pool, mut h) = PoolConfig::default()
            .shards(2)
            .run_skeleton(|_| seq_fn(|x: u64| x * 2));
        for i in 0..100u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut got = vec![];
        while let Some(v) = pool.load_result() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, (0..100u64).map(|i| i * 2).collect::<Vec<_>>());
        pool.wait();
    }

    #[test]
    fn elastic_pool_conserves_tasks_with_cancel() {
        use crate::accel::JobState;
        let (mut pool, mut h) = AccelPool::run(
            PoolConfig::default()
                .shards(2)
                .workers_per_shard(1)
                .elastic(ElasticConfig::default().autoscale(false).window(2)),
            |_s, _w| node_fn(|x: u64| x + 1),
        );
        let mut tokens = vec![];
        for i in 0..500u64 {
            if i % 10 == 0 {
                tokens.push(h.offload_job(i).unwrap());
            } else {
                h.offload(i).unwrap();
            }
        }
        // Revoke half the tracked jobs. Each cancel either wins (the
        // job never reaches a shard and is accounted cancelled) or
        // loses (already claimed at dispatch) — exactly one outcome.
        for t in tokens.iter().step_by(2) {
            t.cancel();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut got = 0u64;
        while pool.load_result().is_some() {
            got += 1;
        }
        let stats = pool.stats();
        assert_eq!(
            got + stats.cancelled_items,
            500,
            "cancel must be never-submitted, not lost: {stats:?}"
        );
        // Every tracked job was single-task, so jobs == items.
        assert_eq!(stats.cancelled_jobs, stats.cancelled_items);
        // Every token is settled one way or the other.
        for t in &tokens {
            assert_ne!(t.state(), JobState::Queued);
        }
        assert_eq!(stats.live_shards, 2);
        pool.wait();
    }

    #[test]
    fn elastic_steal_heals_single_hot_lane() {
        // One client lane, sticky home shard 0, slow workers: shard 1
        // has nothing of its own and must steal or idle.
        let (mut pool, mut h) = AccelPool::run(
            PoolConfig::default()
                .shards(2)
                .workers_per_shard(1)
                .elastic(ElasticConfig::default().autoscale(false).window(1)),
            |_s, _w| {
                node_fn(|x: u64| {
                    std::thread::sleep(Duration::from_micros(50));
                    x * 2
                })
            },
        );
        for i in 0..200u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut got = vec![];
        while let Some(v) = pool.load_result() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, (0..200u64).map(|i| i * 2).collect::<Vec<_>>());
        let stats = pool.stats();
        assert!(stats.steals > 0, "idle shard never stole: {stats:?}");
        assert_eq!(stats.stolen_items, stats.steals); // per-item frames
        pool.wait();
    }

    #[test]
    fn autoscale_grows_under_sustained_backlog() {
        let (mut pool, mut h) = AccelPool::run(
            PoolConfig::default()
                .shards(3)
                .workers_per_shard(1)
                .elastic(
                    ElasticConfig::default()
                        .min_live(1)
                        .window(1)
                        .grow_dwell(Duration::from_micros(50))
                        // Effectively never shrink within the test.
                        .shrink_dwell(Duration::from_secs(3600)),
                ),
            |_s, _w| {
                node_fn(|x: u64| {
                    std::thread::sleep(Duration::from_micros(200));
                    x
                })
            },
        );
        for i in 0..300u64 {
            h.offload(i).unwrap();
        }
        h.finish().unwrap();
        pool.offload_eos();
        let mut n = 0u64;
        while pool.load_result().is_some() {
            n += 1;
        }
        assert_eq!(n, 300);
        let stats = pool.stats();
        assert!(
            stats.scale_ups > 0,
            "sustained backlog never grew the live set: {stats:?}"
        );
        pool.wait();
    }

    #[test]
    fn priority_and_token_api_smoke() {
        use crate::accel::{JobState, Priority};
        let (mut pool, mut h) = AccelPool::run(
            PoolConfig::default()
                .shards(1)
                .workers_per_shard(1)
                .elastic(ElasticConfig::default().autoscale(false)),
            |_s, _w| node_fn(|x: u64| x),
        );
        h.set_priority(Priority::High);
        assert_eq!(h.priority(), Priority::High);
        let t = h.offload_job(7).unwrap();
        h.set_priority(Priority::Low);
        h.offload(9).unwrap();
        h.finish().unwrap();
        pool.offload_eos();
        let mut got = vec![];
        while let Some(v) = pool.load_result() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, vec![7, 9]);
        assert_eq!(t.state(), JobState::Started);
        pool.wait();
    }

    #[test]
    fn legacy_pool_stats_report_all_shards_live() {
        let (mut pool, h) = square_pool(3, 1);
        let s = pool.stats();
        assert_eq!(s.shards, 3);
        assert_eq!(s.live_shards, 3);
        assert_eq!(s.steals + s.cancelled_jobs + s.scale_ups + s.scale_downs, 0);
        drop(h);
        pool.offload_eos();
        while pool.load_result().is_some() {}
        pool.wait();
    }

    #[test]
    fn ordering_config_passthrough() {
        // Smoke that PoolConfig::farm carries collector ordering.
        let cfg = PoolConfig::default()
            .shards(4)
            .placement(Placement::LeastLoaded)
            .batch(32)
            .farm(FarmConfig::default().workers(2).ordered());
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.placement, Placement::LeastLoaded);
        assert_eq!(cfg.batch, 32);
        assert_eq!(cfg.farm.ordering, CollectorOrdering::Ordered);
        assert_eq!(cfg.farm.sched, SchedPolicy::RoundRobin);
    }
}
