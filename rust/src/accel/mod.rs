//! **Self-offloading** (paper §3) as a *service*: software accelerators
//! that scale from one sequential caller to many concurrent clients.
//!
//! The module is layered like the protocols it implements:
//!
//! * [`session`] — the paper's Fig. 3 single-client cycle protocol:
//!   one sequential caller owns one [`Accel`], offloads, pops results,
//!   freezes/thaws between bursts — the 1:1 shape of the original
//!   `ff_farm(true /*accel*/)`. An accelerator is just a composed
//!   skeleton run on spare cores: build one from **any**
//!   [`crate::skeleton::Skeleton`] with
//!   [`crate::skeleton::Skeleton::into_accel`] /
//!   [`crate::skeleton::Skeleton::into_accel_frozen`].
//! * [`client`] — [`AccelHandle`], a cloneable offload capability.
//!   Every clone owns a **private SPSC lane** into an input-arbiter
//!   thread, so any number of client threads can offload concurrently
//!   without locks or atomic RMW on the data path (the arbiter pattern
//!   of §2.3). Handles optionally auto-coalesce tasks into
//!   [`crate::channel::Msg::Batch`] frames to amortize per-item
//!   synchronization on fine-grained tasks.
//! * [`pool`] — [`AccelPool`], which shards offloaded work across N
//!   independently-launched skeleton accelerators — farms by default,
//!   or arbitrary topologies via [`AccelPool::run_skeleton`]
//!   (round-robin or least-loaded placement) — merges their result
//!   streams, and runs the pool-wide lifecycle (`offload_eos` /
//!   `wait_freezing` / `thaw` / `wait`).
//!
//! ```text
//!  client₀ ──spsc──┐
//!  client₁ ──spsc──┤                 ┌─▶ shard 0 (farm accel) ──┐
//!  client₂ ──spsc──┼──▶ arbiter ─────┤                          ├──▶ merged drain
//!      ⋮           │   (placement)   └─▶ shard N-1 ─────────────┘
//!  clientₘ ──spsc──┘
//! ```

pub mod client;
pub mod pool;
pub mod session;

pub use client::AccelHandle;
pub use pool::{AccelPool, Placement, PoolConfig};
pub use session::{Accel, FarmAccel};

/// Errors surfaced by the offload interface.
///
/// Marked `#[non_exhaustive]`: downstream matches must keep a wildcard
/// arm so new failure modes (e.g. future bounded-lane backpressure) can
/// be added without a breaking release.
#[derive(Debug, PartialEq, Eq)]
#[non_exhaustive]
pub enum AccelError {
    /// The accelerator's threads are gone (e.g. a worker panicked) or
    /// the skeleton was poisoned by a protocol violation (e.g. an
    /// ordered farm's worker emitting ≠ 1 result per task).
    Disconnected,
    /// Input channel full (only from [`Accel::try_offload`]).
    WouldBlock,
    /// The current cycle's input stream was closed by
    /// [`Accel::offload_eos`] (or the handle was finished);
    /// [`Accel::thaw`] opens the next cycle.
    Closed,
}

impl std::fmt::Display for AccelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AccelError::Disconnected => write!(f, "accelerator disconnected"),
            AccelError::WouldBlock => write!(f, "accelerator input full"),
            AccelError::Closed => {
                write!(f, "accelerator input stream closed (offload after offload_eos)")
            }
        }
    }
}

impl std::error::Error for AccelError {}
