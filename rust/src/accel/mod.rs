//! **Self-offloading** (paper §3) as a *service*: software accelerators
//! that scale from one sequential caller to many concurrent clients.
//!
//! The module is layered like the protocols it implements:
//!
//! * [`session`] — the paper's Fig. 3 single-client cycle protocol:
//!   one sequential caller owns one [`Accel`], offloads, pops results,
//!   freezes/thaws between bursts — the 1:1 shape of the original
//!   `ff_farm(true /*accel*/)`. An accelerator is just a composed
//!   skeleton run on spare cores: build one from **any**
//!   [`crate::skeleton::Skeleton`] with
//!   [`crate::skeleton::Skeleton::into_accel`] /
//!   [`crate::skeleton::Skeleton::into_accel_frozen`].
//! * [`client`] — [`AccelHandle`], a cloneable offload capability.
//!   Every clone owns a **private SPSC lane** into an input-arbiter
//!   thread, so any number of client threads can offload concurrently
//!   without locks or atomic RMW on the data path (the arbiter pattern
//!   of §2.3). Handles optionally auto-coalesce tasks into
//!   [`crate::channel::Msg::Batch`] frames to amortize per-item
//!   synchronization on fine-grained tasks.
//! * [`pool`] — [`AccelPool`], which shards offloaded work across N
//!   independently-launched skeleton accelerators — farms by default,
//!   or arbitrary topologies via [`AccelPool::run_skeleton`]
//!   (round-robin or least-loaded placement) — merges their result
//!   streams, and runs the pool-wide lifecycle (`offload_eos` /
//!   `wait_freezing` / `thaw` / `wait`).
//!
//! ```text
//!  client₀ ──spsc──┐
//!  client₁ ──spsc──┤                 ┌─▶ shard 0 (farm accel) ──┐
//!  client₂ ──spsc──┼──▶ arbiter ─────┤                          ├──▶ merged drain
//!      ⋮           │   (placement)   └─▶ shard N-1 ─────────────┘
//!  clientₘ ──spsc──┘
//! ```

pub mod client;
pub mod job;
pub mod pool;
pub mod session;

pub use client::AccelHandle;
pub use job::{JobCtl, JobState, JobToken, Priority};
pub use pool::{AccelPool, ElasticConfig, Placement, PoolConfig, PoolStats};
pub use session::{Accel, FarmAccel};

/// Errors surfaced by the offload interface.
///
/// Marked `#[non_exhaustive]`: downstream matches must keep a wildcard
/// arm so new failure modes (e.g. future bounded-lane backpressure) can
/// be added without a breaking release.
#[derive(Debug, PartialEq, Eq)]
#[non_exhaustive]
pub enum AccelError {
    /// The accelerator's threads are gone (e.g. a worker panicked) or
    /// the skeleton was poisoned by a protocol violation (e.g. an
    /// ordered farm's worker emitting ≠ 1 result per task).
    Disconnected,
    /// Input channel full (only from [`Accel::try_offload`]).
    WouldBlock,
    /// The current cycle's input stream was closed by
    /// [`Accel::offload_eos`] (or the handle was finished);
    /// [`Accel::thaw`] opens the next cycle.
    Closed,
    /// Transport failure in the network layer ([`crate::net`]): the
    /// socket died mid-conversation for a reason other than an orderly
    /// peer hang-up (those surface as [`AccelError::Disconnected`]).
    Io(std::io::ErrorKind),
    /// Wire-protocol violation in the network layer ([`crate::net`]):
    /// the peer sent bytes that are not valid `ffnet/1`.
    Protocol(crate::net::frame::ProtocolError),
}

impl std::fmt::Display for AccelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AccelError::Disconnected => write!(f, "accelerator disconnected"),
            AccelError::WouldBlock => write!(f, "accelerator input full"),
            AccelError::Closed => {
                write!(f, "accelerator input stream closed (offload after offload_eos)")
            }
            AccelError::Io(kind) => write!(f, "network transport error: {kind:?}"),
            AccelError::Protocol(e) => write!(f, "wire-protocol violation: {e}"),
        }
    }
}

impl std::error::Error for AccelError {}

#[cfg(test)]
mod tests {
    use super::*;

    // The enum is #[non_exhaustive], so every pre-net caller already
    // carries a wildcard arm — this is the shape such callers use, and
    // it must keep compiling (and keep classifying correctly) with the
    // Io/Protocol variants present.
    fn legacy_classify(e: &AccelError) -> &'static str {
        match e {
            AccelError::Disconnected => "disconnected",
            AccelError::WouldBlock => "retry",
            AccelError::Closed => "closed",
            _ => "other",
        }
    }

    #[test]
    fn existing_callers_see_new_variants_as_other() {
        assert_eq!(legacy_classify(&AccelError::Disconnected), "disconnected");
        assert_eq!(legacy_classify(&AccelError::WouldBlock), "retry");
        assert_eq!(legacy_classify(&AccelError::Closed), "closed");
        assert_eq!(
            legacy_classify(&AccelError::Io(std::io::ErrorKind::TimedOut)),
            "other"
        );
        assert_eq!(
            legacy_classify(&AccelError::Protocol(
                crate::net::frame::ProtocolError::BadMagic
            )),
            "other"
        );
    }

    #[test]
    fn net_variants_display_and_compare() {
        let io = AccelError::Io(std::io::ErrorKind::ConnectionReset);
        assert!(io.to_string().contains("transport"));
        assert_eq!(io, AccelError::Io(std::io::ErrorKind::ConnectionReset));
        let proto = AccelError::Protocol(crate::net::frame::ProtocolError::Oversize {
            len: 99,
            max: 8,
        });
        assert!(proto.to_string().contains("99"));
        assert_ne!(proto, AccelError::Disconnected);
    }
}
