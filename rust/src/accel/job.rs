//! Job-level control for the elastic pool: **priorities** and
//! **cancellation** (ISSUE 9, tentpole c).
//!
//! Every frame a client lane ships to the pool arbiter is a [`Job`]: the
//! task body (one item or a coalesced batch) plus an optional shared
//! [`JobCtl`] and a [`Priority`] class. The tracked offload calls
//! ([`crate::accel::AccelHandle::offload_job`] /
//! [`crate::accel::AccelHandle::offload_batch_job`]) mint one `JobCtl`
//! per frame and hand the caller a [`JobToken`]; the untracked calls
//! (`offload` / `offload_batch`) ship `ctl: None` and stay exactly as
//! cheap as before — zero atomics on the default path.
//!
//! ## The cancel-vs-start race
//!
//! A job is a three-state machine, advanced only by compare-and-swap:
//!
//! ```text
//!            token.cancel()            arbiter try_start()
//!   Queued ────────────────▶ Cancelled        │
//!     └────────────────────────────────▶ Started
//! ```
//!
//! Both edges race on the same `AtomicU8`, so exactly one wins:
//! either the arbiter claims the job (it will run exactly once and the
//! late `cancel()` returns `false`), or the token claims it first (the
//! arbiter drops the frame without dispatching — **cancel ≡
//! never-submitted**). There is no third outcome; the loom model
//! `tests/loom/elastic.rs::cancel_vs_start_exactly_one_winner` explores
//! every interleaving of the two CAS edges.
//!
//! This is the one deliberate exception to the crate's "no atomic RMW
//! on the data path" discipline (paper §2.2): untracked jobs pay
//! nothing, and a tracked job pays exactly one uncontended CAS at
//! dispatch — a *control* edge between two specific threads, not a
//! per-item hot-path operation.

use std::sync::Arc;

use crate::sync::atomic::{AtomicU8, Ordering};

/// Per-offload priority class (tentpole c): the pool arbiter keeps one
/// backlog lane per class and serves `High` before `Normal` before
/// `Low` — except for the aging rule (see
/// [`crate::accel::ElasticConfig::age_every`]), which bounds how long
/// any job can be overtaken and so guarantees starvation freedom.
///
/// Priorities order *deferred* work: a pool whose shards keep up never
/// queues, so priorities only bite once the elastic dispatch window
/// ([`crate::accel::ElasticConfig::window`]) starts holding frames
/// back.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Served first (interactive / progressive-rendering lanes).
    High,
    /// The default class; every untracked offload ships here.
    #[default]
    Normal,
    /// Served last (bulk / background work).
    Low,
}

/// Number of priority classes (backlog lanes per shard).
pub(crate) const PRIORITY_LANES: usize = 3;

impl Priority {
    /// Backlog lane index: 0 (High) is drained before 2 (Low).
    #[inline]
    pub(crate) fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Observable lifecycle of a tracked job ([`JobToken::state`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Published to the pool but not yet claimed by the arbiter —
    /// still cancellable.
    Queued,
    /// Claimed for dispatch: the job runs (exactly once); a late
    /// `cancel()` is a no-op returning `false`.
    Started,
    /// Revoked before dispatch: the job never reaches a shard and
    /// produces no results (cancel ≡ never-submitted).
    Cancelled,
}

const QUEUED: u8 = 0;
const STARTED: u8 = 1;
const CANCELLED: u8 = 2;

/// The shared cancel-vs-start cell of one tracked job. One side is held
/// by the [`JobToken`] (any thread), the other rides inside the frame
/// to the pool arbiter; both race their edge with a single CAS.
#[derive(Debug)]
pub struct JobCtl {
    state: AtomicU8,
}

impl JobCtl {
    /// A fresh, still-`Queued` control cell. Public so the loom models
    /// (and any out-of-tree scheduler built on the pool internals) can
    /// exercise the cancel-vs-start race in isolation; inside the crate
    /// only the tracked offload calls mint one.
    pub fn new() -> Arc<JobCtl> {
        Arc::new(JobCtl {
            state: AtomicU8::new(QUEUED),
        })
    }

    /// Arbiter edge: claim the job for dispatch. `true` means the job
    /// is now [`JobState::Started`] and must run exactly once; `false`
    /// means a cancel won the race and the frame must be dropped.
    ///
    /// AcqRel: the winner's claim orders after the offloader's publish
    /// (Release on the lane) and before the dispatch it gates.
    #[inline]
    pub fn try_start(&self) -> bool {
        // ordering: elastic — the cancel-vs-start CAS edge; exactly one
        // winner in every interleaving (model-checked).
        self.state
            .compare_exchange(QUEUED, STARTED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Token edge: revoke the job. `true` iff this call won the race
    /// (the job was still queued and will never run).
    #[inline]
    pub fn cancel(&self) -> bool {
        // ordering: elastic — the racing revoke edge of the same CAS.
        self.state
            .compare_exchange(QUEUED, CANCELLED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Current state (Acquire, so a `Started`/`Cancelled` answer is
    /// ordered after the edge that produced it).
    #[inline]
    pub fn state(&self) -> JobState {
        // ordering: elastic — Acquire so the answer is ordered after the
        // winning edge.
        match self.state.load(Ordering::Acquire) {
            QUEUED => JobState::Queued,
            STARTED => JobState::Started,
            _ => JobState::Cancelled,
        }
    }
}

/// Cancellation capability for one tracked offload frame, returned by
/// [`crate::accel::AccelHandle::offload_job`] /
/// [`crate::accel::AccelHandle::offload_batch_job`].
///
/// Clone-able and `Send`: any thread may cancel (the net server cancels
/// a whole connection's queued work on disconnect). Dropping the token
/// does **not** cancel — untracked completion is the common case.
#[derive(Debug, Clone)]
pub struct JobToken {
    ctl: Arc<JobCtl>,
}

impl JobToken {
    pub(crate) fn new(ctl: Arc<JobCtl>) -> JobToken {
        JobToken { ctl }
    }

    /// Revoke the job if it has not started. `true` iff the job was
    /// still queued: it will never dispatch and contributes **zero**
    /// results to the pool output (cancel ≡ never-submitted). `false`
    /// means the arbiter already claimed it (it runs exactly once) or
    /// another clone of this token cancelled first.
    #[inline]
    pub fn cancel(&self) -> bool {
        self.ctl.cancel()
    }

    /// Observe the job's lifecycle state.
    #[inline]
    pub fn state(&self) -> JobState {
        self.ctl.state()
    }

    /// `true` once the race is decided either way (started or
    /// cancelled) — the token can be dropped without losing anything.
    #[inline]
    pub fn is_settled(&self) -> bool {
        self.ctl.state() != JobState::Queued
    }
}

/// The task payload of one lane frame.
pub(crate) enum JobBody<I> {
    /// A single task (`offload` / `offload_job`).
    One(I),
    /// A coalesced batch (`flush` / `offload_batch`); the `Vec` is
    /// drawn from the handle's `BatchPool` and returned to it by the
    /// arbiter through the lane's `BatchReturner`.
    Many(Vec<I>),
}

impl<I> JobBody<I> {
    /// Items this frame carries.
    #[inline]
    pub(crate) fn len(&self) -> usize {
        match self {
            JobBody::One(_) => 1,
            JobBody::Many(v) => v.len(),
        }
    }
}

/// One client-lane frame: body + control plane. Untracked frames carry
/// `ctl: None` and cost nothing beyond the enum tag.
pub(crate) struct Job<I> {
    pub(crate) prio: Priority,
    pub(crate) ctl: Option<Arc<JobCtl>>,
    pub(crate) body: JobBody<I>,
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn start_then_cancel_loses() {
        let ctl = JobCtl::new();
        let token = JobToken::new(ctl.clone());
        assert_eq!(token.state(), JobState::Queued);
        assert!(!token.is_settled());
        assert!(ctl.try_start());
        assert!(!token.cancel(), "late cancel must lose");
        assert_eq!(token.state(), JobState::Started);
        assert!(token.is_settled());
    }

    #[test]
    fn cancel_then_start_loses() {
        let ctl = JobCtl::new();
        let token = JobToken::new(ctl.clone());
        assert!(token.cancel());
        assert!(!ctl.try_start(), "arbiter must drop a cancelled frame");
        assert_eq!(token.state(), JobState::Cancelled);
    }

    #[test]
    fn double_cancel_single_winner() {
        let token = JobToken::new(JobCtl::new());
        let clone = token.clone();
        assert!(token.cancel());
        assert!(!clone.cancel(), "only one cancel may claim the job");
        assert_eq!(clone.state(), JobState::Cancelled);
    }

    #[test]
    fn racing_cancel_and_start_resolve_to_one_outcome() {
        // Std smoke of the race the loom model checks exhaustively.
        for _ in 0..200 {
            let ctl = JobCtl::new();
            let token = JobToken::new(ctl.clone());
            let t = std::thread::spawn(move || token.cancel());
            let started = ctl.try_start();
            let cancelled = t.join().unwrap();
            assert!(
                started ^ cancelled,
                "exactly one edge wins (started={started}, cancelled={cancelled})"
            );
        }
    }

    #[test]
    fn priority_lane_order() {
        assert!(Priority::High.lane() < Priority::Normal.lane());
        assert!(Priority::Normal.lane() < Priority::Low.lane());
        assert_eq!(Priority::default(), Priority::Normal);
        assert!(Priority::High < Priority::Low, "Ord matches urgency");
    }

    #[test]
    fn body_len() {
        assert_eq!(JobBody::One(7u32).len(), 1);
        assert_eq!(JobBody::Many(vec![1u32, 2, 3]).len(), 3);
        assert_eq!(JobBody::Many(Vec::<u32>::new()).len(), 0);
    }
}
