//! The multi-client **offload capability**: [`AccelHandle`].
//!
//! The paper's Fig. 3 protocol is 1:1 — one sequential caller per
//! accelerator. To serve many concurrent offloaders without giving up
//! the no-RMW discipline, every handle (and every `clone()` of it) owns
//! a **private unbounded SPSC lane** into the pool's input-arbiter
//! thread (the arbiter pattern of §2.3: serialization is provided by a
//! thread, not by atomic read-modify-write operations). The hot path —
//! `offload` — is a plain SPSC push; the only lock in the design guards
//! the *cold* registration path (creating a handle), which happens once
//! per client, not once per task.
//!
//! Handles optionally **auto-coalesce**: with a batch size `b > 1`,
//! tasks are buffered locally and shipped as one
//! [`crate::channel::Msg::Batch`] frame per `b` tasks — one queue slot
//! and one synchronization per run, which is what amortizes the
//! per-item offload overhead on fine-grained tasks (the granularity
//! cliff of `benches/granularity.rs`).
//!
//! Lifecycle: a handle's lane is closed by [`AccelHandle::finish`] (or
//! its `Drop`). The pool's cycle completes when the pool itself called
//! `offload_eos` **and** every handle of the cycle has closed.
//!
//! **Backpressure:** lanes are unbounded, like the session accelerator's
//! offload buffer (`FarmConfig::in_cap = usize::MAX` — the paper's
//! Fig. 3 offload-all-then-pop pattern is deadlock-free only because
//! the offloading side can never block on its own undrained results).
//! `offload` therefore never blocks and never reports `WouldBlock`;
//! memory grows with offered load minus drain rate. Clients that can
//! outrun the pool for long stretches should throttle at the
//! application level (e.g. cap `offloaded` minus observed results per
//! burst) — a bounded-lane variant is future work.

// ffaudit: allow(facade) — cold-path registration epochs, SeqCst-only
// and bumped once per handle open/finish; no hot-path or weak-ordering
// surface for loom to check.
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::job::{Job, JobBody, JobCtl, JobToken, Priority};
use super::AccelError;
use crate::alloc::{BatchPool, BatchReturner, DEFAULT_BATCH_CAP};
use crate::channel::{stream_unbounded, Receiver, Sender};

/// A freshly-registered client lane, travelling from the registry to
/// the input arbiter: the receiving half of the lane (frames are
/// [`Job`] envelopes — body + priority + optional cancel handle) plus
/// the give side of the handle's batch-buffer free lane. The arbiter
/// copies each `Many` body into shard-owned buffers and returns the
/// client's `Vec` through `ret`, so every buffer cycles producer→arbiter
/// →producer over SPSC paths and the handle's steady-state offload path
/// allocates nothing.
pub(crate) struct NewLane<T: Send + 'static> {
    pub(crate) rx: Receiver<Job<T>>,
    pub(crate) ret: BatchReturner<T>,
}

/// Shared registry of client lanes. Registration is the cold path: it
/// takes a short mutex to serialize concurrent `clone()`s onto the
/// single registration stream; offloads never touch it.
///
/// The registry also keeps the **registration epoch counters**: every
/// handle bumps `opened` when its lane registers and `finished` when
/// its close path runs (`finish()` or `Drop` — even a panicking client
/// thread runs it during unwind). `opened > finished` therefore means
/// some handle was *leaked* (`mem::forget`, a handle stranded in a
/// poisoned mutex): its lane will never send EOS and its sender ring
/// never reports the producer side gone, which is what used to wedge
/// `AccelPool::wait` forever. The pool's `Park`-mode drain uses the
/// counter gap to detect that state and surface
/// [`AccelError::Disconnected`].
pub(crate) struct LaneRegistry<T: Send + 'static> {
    reg_tx: Mutex<Sender<NewLane<T>>>,
    opened: AtomicU64,
    finished: AtomicU64,
}

impl<T: Send + 'static> LaneRegistry<T> {
    /// Create a registry; the returned receiver goes to the arbiter.
    pub(crate) fn create() -> (Arc<Self>, Receiver<NewLane<T>>) {
        let (reg_tx, reg_rx) = stream_unbounded::<NewLane<T>>();
        (
            Arc::new(LaneRegistry {
                reg_tx: Mutex::new(reg_tx),
                opened: AtomicU64::new(0),
                finished: AtomicU64::new(0),
            }),
            reg_rx,
        )
    }

    /// Open a fresh private lane and announce it to the arbiter,
    /// returning the sending half plus the take side of the lane's
    /// batch-buffer free lane. If the arbiter is gone, the lane's
    /// receiving half is dropped and every send on the returned sender
    /// reports disconnection.
    pub(crate) fn open_lane(&self) -> (Sender<Job<T>>, BatchPool<T>) {
        let (lane_tx, lane_rx) = stream_unbounded::<Job<T>>();
        let (batch_pool, ret) = BatchPool::with_cap(DEFAULT_BATCH_CAP);
        self.opened.fetch_add(1, Ordering::SeqCst);
        let _ = self
            .reg_tx
            .lock()
            .expect("lane registry lock")
            .send(NewLane { rx: lane_rx, ret });
        (lane_tx, batch_pool)
    }

    pub(crate) fn note_finished(&self) {
        self.finished.fetch_add(1, Ordering::SeqCst);
    }

    /// Lanes ever opened (cumulative across cycles).
    pub(crate) fn opened(&self) -> u64 {
        self.opened.load(Ordering::SeqCst)
    }

    /// Lanes whose handle ran its close path (cumulative).
    pub(crate) fn finished(&self) -> u64 {
        self.finished.load(Ordering::SeqCst)
    }
}

/// A cloneable offload capability into an [`super::AccelPool`].
///
/// Each clone owns a private SPSC lane; per-handle FIFO order is
/// preserved end-to-end through the arbiter (and, with an ordered
/// single-shard pool, all the way to the merged result stream).
///
/// Migrating from the single-client [`super::Accel`] is two lines:
///
/// ```text
/// let mut acc = farm(cfg, |w| seq(worker(w))).into_accel();   // before
/// let (mut pool, mut h) = AccelPool::run(pool_cfg, factory);  // after
/// acc.offload(t)?  →  h.offload(t)?     (h.clone() for more clients)
/// acc.load_result()  →  pool.load_result()
/// ```
pub struct AccelHandle<T: Send + 'static> {
    lane: Sender<Job<T>>,
    registry: Arc<LaneRegistry<T>>,
    /// Local coalescing buffer (flushed at `batch` items). Replenished
    /// from the handle's batch free lane: the pool arbiter returns every
    /// unpacked frame, so a draining client re-uses the same few `Vec`s
    /// forever — the steady-state offload path allocates nothing.
    buf: Vec<T>,
    batch: usize,
    /// Batch-buffer free lane (take side); the arbiter holds the give
    /// side (it travelled in this lane's [`NewLane`]).
    batch_pool: BatchPool<T>,
    /// Priority class stamped on every subsequent frame
    /// ([`AccelHandle::set_priority`]).
    prio: Priority,
    /// Tasks offloaded through this handle (including still-buffered).
    pub offloaded: u64,
    closed: bool,
}

impl<T: Send + 'static> AccelHandle<T> {
    pub(crate) fn new(registry: Arc<LaneRegistry<T>>, batch: usize) -> Self {
        let (lane, batch_pool) = registry.open_lane();
        AccelHandle {
            lane,
            registry,
            buf: Vec::new(),
            batch: batch.max(1),
            batch_pool,
            prio: Priority::default(),
            offloaded: 0,
            closed: false,
        }
    }

    /// Ship one frame down the lane, stamped with the handle's current
    /// priority class.
    #[inline]
    fn send_job(&mut self, ctl: Option<Arc<JobCtl>>, body: JobBody<T>) -> Result<(), AccelError> {
        self.lane
            .send(Job {
                prio: self.prio,
                ctl,
                body,
            })
            .map_err(|_| AccelError::Disconnected)
    }

    /// Auto-coalescing threshold: tasks per shipped batch frame. `1`
    /// disables coalescing (every task is its own frame).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Change the coalescing threshold for subsequent offloads (flushes
    /// the current buffer first so order is preserved).
    pub fn set_batch(&mut self, batch: usize) -> Result<(), AccelError> {
        self.flush()?;
        self.batch = batch.max(1);
        Ok(())
    }

    /// Offload one task. With coalescing enabled the task may sit in
    /// the local buffer until `batch` tasks accumulate (or [`flush`] /
    /// [`finish`] ships the partial run).
    ///
    /// [`flush`]: AccelHandle::flush
    /// [`finish`]: AccelHandle::finish
    #[inline]
    pub fn offload(&mut self, task: T) -> Result<(), AccelError> {
        if self.closed {
            return Err(AccelError::Closed);
        }
        if self.batch <= 1 {
            self.send_job(None, JobBody::One(task))?;
        } else {
            self.buf.push(task);
            if self.buf.len() >= self.batch {
                self.flush()?;
            }
        }
        self.offloaded += 1;
        Ok(())
    }

    /// Offload one **tracked** task: like [`AccelHandle::offload`]
    /// (minus coalescing — the frame ships immediately, after flushing
    /// any buffered tasks so per-handle FIFO holds) but returns a
    /// [`JobToken`] that can revoke the task as long as the pool has not
    /// started it. A cancelled job contributes zero results — exactly as
    /// if it was never offloaded. Costs one `Arc` allocation and one CAS
    /// at dispatch; the untracked calls stay atomics-free.
    pub fn offload_job(&mut self, task: T) -> Result<JobToken, AccelError> {
        if self.closed {
            return Err(AccelError::Closed);
        }
        self.flush()?;
        let ctl = JobCtl::new();
        self.send_job(Some(ctl.clone()), JobBody::One(task))?;
        self.offloaded += 1;
        Ok(JobToken::new(ctl))
    }

    /// Draw a recycled batch buffer for [`AccelHandle::offload_batch`]
    /// (the pool arbiter returns every unpacked frame through this
    /// handle's free lane).
    #[must_use = "the drawn buffer is the batch frame — fill and offload it"]
    pub fn take_batch_buf(&mut self) -> Vec<T> {
        self.batch_pool.take()
    }

    /// Offload a pre-built run of tasks as one frame (after flushing any
    /// buffered tasks, so per-handle FIFO order holds). Draw `tasks`
    /// from [`AccelHandle::take_batch_buf`] to keep sustained batching
    /// allocation-free.
    pub fn offload_batch(&mut self, tasks: Vec<T>) -> Result<(), AccelError> {
        if self.closed {
            return Err(AccelError::Closed);
        }
        self.flush()?;
        let n = tasks.len() as u64;
        self.ship_run(None, tasks)?;
        self.offloaded += n;
        Ok(())
    }

    /// Offload a pre-built run as one **tracked** frame: the whole batch
    /// is one job — one [`JobToken`], cancelled (or started) atomically
    /// as a unit, so a revoked run contributes none of its items.
    pub fn offload_batch_job(&mut self, tasks: Vec<T>) -> Result<JobToken, AccelError> {
        if self.closed {
            return Err(AccelError::Closed);
        }
        self.flush()?;
        let ctl = JobCtl::new();
        if tasks.is_empty() {
            // Nothing to revoke: settle the token as started (zero items
            // "ran") rather than shipping an empty frame that could pin
            // the token in `Queued` forever.
            self.batch_pool.put_back(tasks);
            let started = ctl.try_start();
            debug_assert!(started);
            return Ok(JobToken::new(ctl));
        }
        let n = tasks.len() as u64;
        self.ship_run(Some(ctl.clone()), tasks)?;
        self.offloaded += n;
        Ok(JobToken::new(ctl))
    }

    /// Canonical run framing: empty runs send nothing, single-task runs
    /// degrade to a `One` body (their buffer returns to the free lane
    /// either way), longer runs ship as `Many`.
    fn ship_run(&mut self, ctl: Option<Arc<JobCtl>>, mut tasks: Vec<T>) -> Result<(), AccelError> {
        match tasks.len() {
            0 => {
                self.batch_pool.put_back(tasks);
                Ok(())
            }
            1 => {
                let t = tasks.pop().expect("len checked");
                self.batch_pool.put_back(tasks);
                self.send_job(ctl, JobBody::One(t))
            }
            _ => self.send_job(ctl, JobBody::Many(tasks)),
        }
    }

    /// Ship any buffered tasks now. The next coalescing buffer is drawn
    /// from the handle's free lane (recycled frames returned by the pool
    /// arbiter) — fresh allocation happens only during warmup.
    pub fn flush(&mut self) -> Result<(), AccelError> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let run = std::mem::replace(&mut self.buf, self.batch_pool.take());
        self.ship_run(None, run)
    }

    /// Priority class for subsequent offloads through this handle
    /// (buffered tasks are flushed first, so already-offloaded tasks
    /// keep the class they were offloaded under). Priorities order
    /// *deferred* work inside an elastic pool
    /// ([`super::PoolConfig::elastic`]); legacy eager pools dispatch
    /// every frame immediately and never consult them.
    pub fn set_priority(&mut self, prio: Priority) -> Result<(), AccelError> {
        self.flush()?;
        self.prio = prio;
        Ok(())
    }

    /// The current priority class ([`AccelHandle::set_priority`]).
    pub fn priority(&self) -> Priority {
        self.prio
    }

    /// Batch buffers this handle allocated fresh (its free lane was
    /// empty). Plateaus after warmup when the arbiter keeps up — the
    /// §3.2 "parallel allocator" observable for the offload side.
    pub fn batch_fresh(&self) -> u64 {
        self.batch_pool.fresh
    }

    /// Batch buffers this handle drew recycled from the arbiter.
    pub fn batch_reused(&self) -> u64 {
        self.batch_pool.reused
    }

    /// Close this handle's lane: flushes buffered tasks and tells the
    /// arbiter this client is done for the cycle. Dropping the handle
    /// does the same (ignoring errors).
    pub fn finish(mut self) -> Result<(), AccelError> {
        self.close_lane()
    }

    fn close_lane(&mut self) -> Result<(), AccelError> {
        if self.closed {
            return Ok(());
        }
        let flushed = self.flush();
        self.closed = true;
        let eos = self.lane.send_eos().map_err(|_| AccelError::Disconnected);
        // Count the close even on error: the registration-epoch gap
        // (`opened - finished`) must track *leaked* handles only.
        self.registry.note_finished();
        flushed.and(eos)
    }
}

impl<T: Send + 'static> Clone for AccelHandle<T> {
    /// A clone is a **new client**: it gets its own private lane (and
    /// empty buffer), registered with the arbiter through the cold-path
    /// registry. Clone only live handles you still intend to close —
    /// the pool's cycle waits for every lane to finish.
    fn clone(&self) -> Self {
        AccelHandle::new(self.registry.clone(), self.batch)
    }
}

impl<T: Send + 'static> Drop for AccelHandle<T> {
    fn drop(&mut self) {
        let _ = self.close_lane();
    }
}
