//! The **pipeline** facade (paper §2.4): parallel execution of filters
//! with a direct data dependency.
//!
//! Since the [`crate::skeleton`] combinator algebra landed, a pipeline
//! is just [`seq`]`(a).`[`then`]`(b)` — and a farm stage is
//! `.then(farm(cfg, |w| seq(worker)))`, with the farm's workers free to
//! be whole skeletons themselves. This module keeps the familiar
//! [`Pipeline`] builder as a thin facade over those combinators; its
//! launch methods are deprecated shims for the single
//! [`Skeleton::launch`] path.
//!
//! ```no_run
//! use fastflow::prelude::*;
//!
//! let skel = seq_fn(|x: u64| x + 1)                         // stage 1: node
//!     .then(farm(FarmConfig::default().workers(4), |_| {
//!         seq_fn(|x: u64| x * 2)                            // stage 2: farm
//!     }))
//!     .then(seq_fn(|x: u64| x - 1));                        // stage 3: node
//! let mut acc = skel.into_accel();
//! acc.offload(10).unwrap();
//! acc.offload_eos();
//! assert_eq!(acc.load_result(), Some(21));
//! acc.wait();
//! ```
//!
//! [`seq`]: crate::skeleton::seq
//! [`then`]: Skeleton::then

use std::marker::PhantomData;

use crate::farm::{farm, Farm, FarmConfig};
use crate::node::{Node, RunMode};
use crate::sched::MappingPolicy;
use crate::skeleton::builder::{seq, SeqNode, Skeleton, Then};
use crate::skeleton::LaunchedSkeleton;
use crate::DEFAULT_QUEUE_CAP;

// Re-exported so pre-combinator imports keep compiling.
pub use crate::skeleton::builder::WireCtx;

/// Pipeline builder — a facade over [`Skeleton::then`] kept for
/// familiarity; [`Pipeline::into_skeleton`] hands back the underlying
/// combinator value.
#[must_use = "skeletons are blueprints: nothing runs until launch"]
pub struct Pipeline<I: Send + 'static, O: Send + 'static, S: Skeleton<I, O>> {
    skel: S,
    cap: usize,
    mapping: MappingPolicy,
    explicit_cores: Vec<usize>,
    _pd: PhantomData<fn(I) -> O>,
}

impl<N: Node + 'static> Pipeline<N::In, N::Out, SeqNode<N>> {
    /// Start a pipeline with a first stage.
    pub fn new(node: N) -> Self {
        Pipeline {
            skel: seq(node),
            cap: DEFAULT_QUEUE_CAP,
            mapping: MappingPolicy::None,
            explicit_cores: vec![],
            _pd: PhantomData,
        }
    }
}

impl<I: Send + 'static, O: Send + 'static, S: Skeleton<I, O>> Pipeline<I, O, S> {
    /// Append a node stage.
    pub fn then<N>(self, node: N) -> Pipeline<I, N::Out, Then<S, SeqNode<N>, O>>
    where
        N: Node<In = O> + 'static,
    {
        let cap = self.cap;
        Pipeline {
            skel: self.skel.then(seq(node).cap(cap)),
            cap,
            mapping: self.mapping,
            explicit_cores: self.explicit_cores,
            _pd: PhantomData,
        }
    }

    /// Append a farm stage (nesting) with plain-node workers. For
    /// skeleton-valued workers, use the [`farm`] combinator directly.
    pub fn then_farm<W, F>(
        self,
        cfg: FarmConfig,
        mut factory: F,
    ) -> Pipeline<I, W::Out, Then<S, Farm<O, W::Out, SeqNode<W>>, O>>
    where
        W: Node<In = O> + 'static,
        F: FnMut(usize) -> W,
    {
        let cap = self.cap;
        Pipeline {
            skel: self.skel.then(farm(cfg, move |wi| seq(factory(wi)))),
            cap,
            mapping: self.mapping,
            explicit_cores: self.explicit_cores,
            _pd: PhantomData,
        }
    }

    /// Default queue capacity for subsequently-added links.
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.cap = cap.max(1);
        self
    }

    /// Thread→core mapping policy for the whole pipeline.
    pub fn mapping(mut self, m: MappingPolicy) -> Self {
        self.mapping = m;
        self
    }

    /// Unwrap into the underlying [`Skeleton`] combinator value (the
    /// migration path off this facade).
    pub fn into_skeleton(self) -> S {
        self.skel
    }

    /// Shared body of the deprecated launch shims.
    fn launch_inner(self, mode: RunMode) -> LaunchedSkeleton<I, O> {
        let (skel, mapping, cores) = (self.skel, self.mapping, self.explicit_cores);
        skel.launch_pinned(mode, mapping, &cores)
    }

    /// Launch with an output stream, one-shot lifecycle.
    ///
    /// Note: the unified launch path gives the pipeline an **unbounded**
    /// output stream (the old `launch` bounded it at `queue_cap`), so
    /// the Fig. 3 offload-all-then-pop pattern can never deadlock;
    /// callers that relied on output backpressure should throttle at
    /// the application level.
    #[deprecated(since = "0.2.0", note = "use `Skeleton::launch(RunMode::RunToEnd)`")]
    #[must_use = "a launched skeleton must be driven and joined"]
    pub fn launch(self) -> LaunchedSkeleton<I, O> {
        self.launch_inner(RunMode::RunToEnd)
    }

    /// Launch for accelerator use (identical to `launch`; wrap the
    /// result in [`crate::accel::Accel::from_skeleton`]).
    #[deprecated(since = "0.2.0", note = "use `Skeleton::into_accel()`")]
    #[must_use = "a launched skeleton must be driven and joined"]
    pub fn launch_accel(self) -> LaunchedSkeleton<I, O> {
        self.launch_inner(RunMode::RunToEnd)
    }

    /// Launch with an output stream in freeze mode.
    #[deprecated(since = "0.2.0", note = "use `Skeleton::into_accel_frozen()`")]
    #[must_use = "a launched skeleton must be driven and joined"]
    pub fn launch_accel_freeze(self) -> LaunchedSkeleton<I, O> {
        self.launch_inner(RunMode::RunThenFreeze)
    }

    /// Launch with explicit run mode.
    #[deprecated(since = "0.2.0", note = "use `Skeleton::launch(mode)`")]
    #[must_use = "a launched skeleton must be driven and joined"]
    pub fn launch_mode(self, mode: RunMode) -> LaunchedSkeleton<I, O> {
        self.launch_inner(mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Msg;
    use crate::node::{node_fn, Outbox, Svc};
    use crate::skeleton::seq_fn;

    #[test]
    fn two_stage_pipeline_composes_functions() {
        let skel = seq_fn(|x: u64| x + 1)
            .then(seq_fn(|x: u64| x * 3))
            .launch(RunMode::RunToEnd);
        let mut input = skel.input;
        let mut output = skel.output.unwrap();
        for i in 0..100u64 {
            input.send(i).unwrap();
        }
        input.send_eos().unwrap();
        let mut got = vec![];
        loop {
            match output.recv() {
                Msg::Task(v) => got.push(v),
                Msg::Batch(vs) => got.extend(vs),
                Msg::Eos => break,
            }
        }
        assert_eq!(got, (0..100u64).map(|x| (x + 1) * 3).collect::<Vec<_>>());
    }

    #[test]
    fn pipeline_preserves_order() {
        let skel = seq_fn(|x: u64| x)
            .then(seq_fn(|x: u64| x))
            .then(seq_fn(|x: u64| x))
            .launch(RunMode::RunToEnd);
        let mut input = skel.input;
        let mut output = skel.output.unwrap();
        let pusher = std::thread::spawn(move || {
            for i in 0..10_000u64 {
                input.send(i).unwrap();
            }
            input.send_eos().unwrap();
        });
        let mut expect = 0u64;
        loop {
            match output.recv() {
                Msg::Task(v) => {
                    assert_eq!(v, expect);
                    expect += 1;
                }
                Msg::Batch(_) => unreachable!("no batches sent"),
                Msg::Eos => break,
            }
        }
        pusher.join().unwrap();
        assert_eq!(expect, 10_000);
    }

    #[test]
    fn farm_nested_in_pipeline() {
        let mut acc = seq_fn(|x: u64| x + 1)
            .then(farm(FarmConfig::default().workers(4).ordered(), |_| {
                seq_fn(|x: u64| x * 2)
            }))
            .then(seq_fn(|x: u64| x - 1))
            .into_accel();
        for i in 0..1000 {
            acc.offload(i).unwrap();
        }
        acc.offload_eos();
        let mut got = vec![];
        while let Some(v) = acc.load_result() {
            got.push(v);
        }
        // ordered farm keeps pipeline order end-to-end
        assert_eq!(got, (0..1000u64).map(|x| (x + 1) * 2 - 1).collect::<Vec<_>>());
        acc.wait();
    }

    #[test]
    fn multi_emission_stage_expands_stream() {
        struct Expander;
        impl Node for Expander {
            type In = u64;
            type Out = u64;
            fn svc(&mut self, t: u64, out: &mut Outbox<'_, u64>) -> Svc {
                out.send(t);
                out.send(t + 100);
                Svc::GoOn
            }
        }
        let skel = crate::skeleton::seq(Expander)
            .then(seq_fn(|x: u64| x))
            .launch(RunMode::RunToEnd);
        let mut input = skel.input;
        let mut output = skel.output.unwrap();
        input.send(1).unwrap();
        input.send(2).unwrap();
        input.send_eos().unwrap();
        let mut got = vec![];
        loop {
            match output.recv() {
                Msg::Task(v) => got.push(v),
                Msg::Batch(vs) => got.extend(vs),
                Msg::Eos => break,
            }
        }
        assert_eq!(got, vec![1, 101, 2, 102]);
    }

    #[test]
    fn pipeline_freeze_thaw_cycles() {
        let mut acc = seq_fn(|x: u64| x * 2)
            .then(seq_fn(|x: u64| x + 1))
            .into_accel_frozen();
        for cycle in 0..3u64 {
            if cycle > 0 {
                acc.thaw();
            }
            acc.offload(cycle).unwrap();
            acc.offload_eos();
            assert_eq!(acc.load_result(), Some(cycle * 2 + 1));
            assert_eq!(acc.load_result(), None);
            acc.wait_freezing();
        }
        acc.wait();
    }

    #[test]
    fn facade_builds_the_same_skeleton() {
        // The Pipeline facade and the combinators must wire identical
        // topologies; compare thread counts and results.
        let facade = Pipeline::new(node_fn(|x: u64| x + 1))
            .then_farm(FarmConfig::default().workers(2).ordered(), |_| {
                node_fn(|x: u64| x * 2)
            })
            .then(node_fn(|x: u64| x - 1))
            .into_skeleton();
        let combinators = seq_fn(|x: u64| x + 1)
            .then(farm(FarmConfig::default().workers(2).ordered(), |_| {
                seq_fn(|x: u64| x * 2)
            }))
            .then(seq_fn(|x: u64| x - 1));
        assert_eq!(facade.thread_count(), combinators.thread_count());
        let mut acc = facade.into_accel();
        for i in 0..100 {
            acc.offload(i).unwrap();
        }
        acc.offload_eos();
        let mut got = vec![];
        while let Some(v) = acc.load_result() {
            got.push(v);
        }
        assert_eq!(got, (0..100u64).map(|x| (x + 1) * 2 - 1).collect::<Vec<_>>());
        acc.wait();
    }
}
