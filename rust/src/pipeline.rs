//! The **pipeline** skeleton (paper §2.4): parallel execution of filters
//! with a direct data dependency, plus arbitrary nesting of farms as
//! stages (farm-in-pipeline composition — the paper's "their arbitrary
//! nesting and composition").
//!
//! A pipeline is assembled back-to-front at launch: each stage is handed
//! the sender of its successor's input queue, so every link is one
//! lock-free SPSC stream and no pump threads exist.
//!
//! ```no_run
//! use fastflow::pipeline::Pipeline;
//! use fastflow::farm::FarmConfig;
//! use fastflow::accel::Accel;
//!
//! use fastflow::node::node_fn;
//! let pipe = Pipeline::new(node_fn(|x: u64| x + 1))   // stage 1: node
//!     .then_farm(FarmConfig::default().workers(4), |_| node_fn(|x: u64| x * 2)) // stage 2: farm
//!     .then(node_fn(|x: u64| x - 1));               // stage 3: node
//! let mut acc: Accel<u64, u64> = Accel::from_skeleton(pipe.launch_accel());
//! acc.offload(10).unwrap();
//! acc.offload_eos();
//! assert_eq!(acc.load_result(), Some(21));
//! acc.wait();
//! ```

use std::marker::PhantomData;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::channel::{stream, Sender};
use crate::farm::{farm_thread_count, wire_farm, FarmConfig};
use crate::node::{Lifecycle, Node, NodeRunner, OutTarget, RunMode};
use crate::sched::{CpuMap, MappingPolicy};
use crate::skeleton::LaunchedSkeleton;
use crate::trace::NodeTrace;
use crate::DEFAULT_QUEUE_CAP;

/// Wiring context threaded through stage construction.
pub struct WireCtx<'a> {
    lifecycle: &'a Arc<Lifecycle>,
    /// Shared poison flag (raised by any farm stage on a protocol
    /// violation — see [`LaunchedSkeleton::poison`]).
    poison: &'a Arc<std::sync::atomic::AtomicBool>,
    cpu_map: &'a CpuMap,
    next_thread: usize,
    joins: &'a mut Vec<JoinHandle<()>>,
    traces: &'a mut Vec<(String, Arc<NodeTrace>)>,
    stage_idx: usize,
}

/// A pipeline stage: knows how many threads it runs and how to wire
/// itself given its downstream target, returning its input sender.
pub trait Stage<I: Send + 'static, O: Send + 'static>: Sized {
    fn thread_count(&self) -> usize;
    fn wire(self, out: OutTarget<O>, ctx: &mut WireCtx<'_>) -> Sender<I>;
}

/// A single [`Node`] as a stage.
pub struct NodeStage<N> {
    node: N,
    cap: usize,
}

impl<N: Node + 'static> Stage<N::In, N::Out> for NodeStage<N> {
    fn thread_count(&self) -> usize {
        1
    }

    fn wire(self, out: OutTarget<N::Out>, ctx: &mut WireCtx<'_>) -> Sender<N::In> {
        let (tx, rx) = stream::<N::In>(self.cap);
        let trace = NodeTrace::new();
        let name = format!("stage-{}", ctx.stage_idx);
        ctx.traces.push((name.clone(), trace.clone()));
        let tid = ctx.next_thread;
        ctx.next_thread += 1;
        ctx.stage_idx += 1;
        ctx.joins.push(
            NodeRunner {
                node: self.node,
                rx,
                out,
                lifecycle: ctx.lifecycle.clone(),
                trace,
                pin_to: ctx.cpu_map.core_for(tid),
                name,
            }
            .spawn(),
        );
        tx
    }
}

/// A whole farm as a stage (farm-in-pipeline nesting).
pub struct FarmStage<W, F> {
    cfg: FarmConfig,
    factory: F,
    _pd: PhantomData<fn() -> W>,
}

impl<I, O, W, F> Stage<I, O> for FarmStage<W, F>
where
    I: Send + 'static,
    O: Send + 'static,
    W: Node<In = I, Out = O> + 'static,
    F: FnMut(usize) -> W,
{
    fn thread_count(&self) -> usize {
        farm_thread_count(&self.cfg, true)
    }

    fn wire(self, out: OutTarget<O>, ctx: &mut WireCtx<'_>) -> Sender<I> {
        let base = ctx.next_thread;
        ctx.next_thread += farm_thread_count(&self.cfg, true);
        ctx.stage_idx += 1;
        let out_target = match out {
            OutTarget::Chan(tx) => Some(OutTarget::Chan(tx)),
            OutTarget::Discard => Some(OutTarget::Discard),
        };
        wire_farm(
            &self.cfg,
            self.factory,
            out_target,
            ctx.lifecycle,
            ctx.poison,
            base,
            ctx.cpu_map,
            ctx.joins,
            ctx.traces,
        )
    }
}

/// Two stages composed: `S1 → S2`.
pub struct Compose<S1, S2, M> {
    first: S1,
    second: S2,
    _pd: PhantomData<fn() -> M>,
}

impl<I, M, O, S1, S2> Stage<I, O> for Compose<S1, S2, M>
where
    I: Send + 'static,
    M: Send + 'static,
    O: Send + 'static,
    S1: Stage<I, M>,
    S2: Stage<M, O>,
{
    fn thread_count(&self) -> usize {
        self.first.thread_count() + self.second.thread_count()
    }

    fn wire(self, out: OutTarget<O>, ctx: &mut WireCtx<'_>) -> Sender<I> {
        // Back-to-front: reserve first-stage thread ids before the
        // second stage consumes ids, to keep pinning front-to-back.
        let first_threads = self.first.thread_count();
        let first_base = ctx.next_thread;
        ctx.next_thread += first_threads;
        let mid_tx = self.second.wire(out, ctx);
        // Rewind for the first stage's ids.
        let saved = ctx.next_thread;
        ctx.next_thread = first_base;
        let tx = self.first.wire(OutTarget::Chan(mid_tx), ctx);
        ctx.next_thread = saved;
        tx
    }
}

/// Pipeline builder.
pub struct Pipeline<I: Send + 'static, O: Send + 'static, S: Stage<I, O>> {
    stage: S,
    cap: usize,
    mapping: MappingPolicy,
    explicit_cores: Vec<usize>,
    _pd: PhantomData<fn(I) -> O>,
}

impl<N: Node + 'static> Pipeline<N::In, N::Out, NodeStage<N>> {
    /// Start a pipeline with a first stage.
    pub fn new(node: N) -> Self {
        Pipeline {
            stage: NodeStage {
                node,
                cap: DEFAULT_QUEUE_CAP,
            },
            cap: DEFAULT_QUEUE_CAP,
            mapping: MappingPolicy::None,
            explicit_cores: vec![],
            _pd: PhantomData,
        }
    }
}

impl<I: Send + 'static, O: Send + 'static, S: Stage<I, O>> Pipeline<I, O, S> {
    /// Append a node stage.
    pub fn then<N>(self, node: N) -> Pipeline<I, N::Out, Compose<S, NodeStage<N>, O>>
    where
        N: Node<In = O> + 'static,
    {
        let cap = self.cap;
        Pipeline {
            stage: Compose {
                first: self.stage,
                second: NodeStage { node, cap },
                _pd: PhantomData,
            },
            cap,
            mapping: self.mapping,
            explicit_cores: self.explicit_cores,
            _pd: PhantomData,
        }
    }

    /// Append a farm stage (nesting).
    pub fn then_farm<W, F>(
        self,
        cfg: FarmConfig,
        factory: F,
    ) -> Pipeline<I, W::Out, Compose<S, FarmStage<W, F>, O>>
    where
        W: Node<In = O> + 'static,
        F: FnMut(usize) -> W,
    {
        let cap = self.cap;
        Pipeline {
            stage: Compose {
                first: self.stage,
                second: FarmStage {
                    cfg,
                    factory,
                    _pd: PhantomData,
                },
                _pd: PhantomData,
            },
            cap,
            mapping: self.mapping,
            explicit_cores: self.explicit_cores,
            _pd: PhantomData,
        }
    }

    /// Default queue capacity for subsequently-added links.
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.cap = cap.max(1);
        self
    }

    /// Thread→core mapping policy for the whole pipeline.
    pub fn mapping(mut self, m: MappingPolicy) -> Self {
        self.mapping = m;
        self
    }

    /// Launch with an output stream, one-shot lifecycle.
    pub fn launch(self) -> LaunchedSkeleton<I, O> {
        self.launch_mode(RunMode::RunToEnd)
    }

    /// Launch with an output stream, one-shot lifecycle (accelerator use:
    /// wrap the result in [`crate::accel::Accel::from_skeleton`]).
    pub fn launch_accel(self) -> LaunchedSkeleton<I, O> {
        self.launch_mode(RunMode::RunToEnd)
    }

    /// Launch with an output stream in freeze mode.
    pub fn launch_accel_freeze(self) -> LaunchedSkeleton<I, O> {
        self.launch_mode(RunMode::RunThenFreeze)
    }

    /// Launch with explicit run mode.
    pub fn launch_mode(self, mode: RunMode) -> LaunchedSkeleton<I, O> {
        let total = self.stage.thread_count();
        let lifecycle = Lifecycle::new(total, mode);
        let cpu_map = CpuMap::build(self.mapping, total, &self.explicit_cores);
        let mut joins = Vec::with_capacity(total);
        let mut traces = Vec::with_capacity(total);
        let (out_tx, out_rx) = stream::<O>(self.cap);
        let poison = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut ctx = WireCtx {
            lifecycle: &lifecycle,
            poison: &poison,
            cpu_map: &cpu_map,
            next_thread: 0,
            joins: &mut joins,
            traces: &mut traces,
            stage_idx: 0,
        };
        let input = self.stage.wire(OutTarget::Chan(out_tx), &mut ctx);
        LaunchedSkeleton {
            input,
            output: Some(out_rx),
            lifecycle,
            joins,
            traces,
            poison,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::Accel;
    use crate::node::node_fn;
    use crate::channel::Msg;

    #[test]
    fn two_stage_pipeline_composes_functions() {
        let skel = Pipeline::new(node_fn(|x: u64| x + 1))
            .then(node_fn(|x: u64| x * 3))
            .launch();
        let mut input = skel.input;
        let mut output = skel.output.unwrap();
        for i in 0..100u64 {
            input.send(i).unwrap();
        }
        input.send_eos().unwrap();
        let mut got = vec![];
        loop {
            match output.recv() {
                Msg::Task(v) => got.push(v),
                Msg::Batch(vs) => got.extend(vs),
                Msg::Eos => break,
            }
        }
        assert_eq!(got, (0..100u64).map(|x| (x + 1) * 3).collect::<Vec<_>>());
    }

    #[test]
    fn pipeline_preserves_order() {
        let skel = Pipeline::new(node_fn(|x: u64| x))
            .then(node_fn(|x: u64| x))
            .then(node_fn(|x: u64| x))
            .launch();
        let mut input = skel.input;
        let mut output = skel.output.unwrap();
        let pusher = std::thread::spawn(move || {
            for i in 0..10_000u64 {
                input.send(i).unwrap();
            }
            input.send_eos().unwrap();
        });
        let mut expect = 0u64;
        loop {
            match output.recv() {
                Msg::Task(v) => {
                    assert_eq!(v, expect);
                    expect += 1;
                }
                Msg::Batch(_) => unreachable!("no batches sent"),
                Msg::Eos => break,
            }
        }
        pusher.join().unwrap();
        assert_eq!(expect, 10_000);
    }

    #[test]
    fn farm_nested_in_pipeline() {
        let pipe = Pipeline::new(node_fn(|x: u64| x + 1))
            .then_farm(FarmConfig::default().workers(4).ordered(), |_| {
                node_fn(|x: u64| x * 2)
            })
            .then(node_fn(|x: u64| x - 1));
        let mut acc: Accel<u64, u64> = Accel::from_skeleton(pipe.launch_accel());
        for i in 0..1000 {
            acc.offload(i).unwrap();
        }
        acc.offload_eos();
        let mut got = vec![];
        while let Some(v) = acc.load_result() {
            got.push(v);
        }
        // ordered farm keeps pipeline order end-to-end
        assert_eq!(got, (0..1000u64).map(|x| (x + 1) * 2 - 1).collect::<Vec<_>>());
        acc.wait();
    }

    #[test]
    fn multi_emission_stage_expands_stream() {
        struct Expander;
        impl Node for Expander {
            type In = u64;
            type Out = u64;
            fn svc(
                &mut self,
                t: u64,
                out: &mut crate::node::Outbox<'_, u64>,
            ) -> crate::node::Svc {
                out.send(t);
                out.send(t + 100);
                crate::node::Svc::GoOn
            }
        }
        let skel = Pipeline::new(Expander).then(node_fn(|x: u64| x)).launch();
        let mut input = skel.input;
        let mut output = skel.output.unwrap();
        input.send(1).unwrap();
        input.send(2).unwrap();
        input.send_eos().unwrap();
        let mut got = vec![];
        loop {
            match output.recv() {
                Msg::Task(v) => got.push(v),
                Msg::Batch(vs) => got.extend(vs),
                Msg::Eos => break,
            }
        }
        assert_eq!(got, vec![1, 101, 2, 102]);
    }

    #[test]
    fn pipeline_freeze_thaw_cycles() {
        let pipe = Pipeline::new(node_fn(|x: u64| x * 2)).then(node_fn(|x: u64| x + 1));
        let mut acc: Accel<u64, u64> = Accel::from_skeleton(pipe.launch_accel_freeze());
        for cycle in 0..3u64 {
            if cycle > 0 {
                acc.thaw();
            }
            acc.offload(cycle).unwrap();
            acc.offload_eos();
            assert_eq!(acc.load_result(), Some(cycle * 2 + 1));
            assert_eq!(acc.load_result(), None);
            acc.wait_freezing();
        }
        acc.wait();
    }
}
