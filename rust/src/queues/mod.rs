//! Low-level programming tier (paper §2.3): one-to-many, many-to-one and
//! many-to-many channels built **without locks or atomic RMW
//! operations** — SPMC, MPSC and MPMC queues realised as sets of SPSC
//! queues plus an *arbiter thread* enforcing the serialization of
//! producers/consumers.
//!
//! The farm's Emitter/Collector are specialized inlined versions of these
//! arbiters; this module exposes the general-purpose standalone forms
//! usable as plain channels among arbitrary threads.


use std::thread::JoinHandle;

use crate::channel::{stream, Msg, Receiver, Sender};
use crate::util::{Backoff, Doorbell, WaitCfg, WaitMode};
use crate::DEFAULT_QUEUE_CAP;

/// Round-robin with skip-if-full routing of one frame to some consumer
/// (work happily drains past a slow consumer). Shared by the SPMC and
/// MPMC arbiters, which unpack [`Msg::Batch`] runs through it so every
/// consumer still receives individual tasks.
///
/// Consumers whose receiving half was dropped are removed from the
/// rotation (a dead ring with spare slots would otherwise swallow the
/// frame; a *full* dead ring would spin this loop forever — the
/// regression `spmc_all_consumers_gone_poisons_producer` covers it).
/// When **no** live consumer remains the frame is handed back via
/// `Err`, and the calling arbiter exits — poisoning the producer-side
/// stream, whose sends then report `Disconnected`. The all-full wait
/// rides the spin→yield→park escalation, parking on *any* consumer's
/// space doorbell.
fn route_skip_full<T: Send>(
    outs: &mut [Sender<T>],
    next: &mut usize,
    mut frame: T,
    wait: &WaitCfg,
) -> Result<(), T> {
    let n = outs.len();
    let mut backoff = Backoff::new();
    loop {
        let mut any_alive = false;
        for k in 0..n {
            let c = (*next + k) % n;
            if !outs[c].peer_alive() {
                continue; // dropped from rotation
            }
            any_alive = true;
            match outs[c].try_send(frame) {
                Ok(()) => {
                    *next = (c + 1) % n;
                    return Ok(());
                }
                Err(crate::spsc::Full(f)) => frame = f,
            }
        }
        if !any_alive {
            return Err(frame);
        }
        if wait.wants_park(&mut backoff) {
            let bells: Vec<&Doorbell> = outs.iter().filter_map(|o| o.space_bell()).collect();
            wait.park_any(&bells, || {
                outs.iter().all(|o| !o.peer_alive() || o.is_full())
            });
        } else {
            backoff.snooze();
        }
    }
}

/// One-to-many: a single producer feeds `n` consumers through an Emitter
/// arbiter (round-robin dispatch).
///
/// Returns (producer sender, consumer receivers, arbiter join handle).
/// The arbiter exits after forwarding EOS to every consumer.
pub fn spmc<T: Send + 'static>(
    consumers: usize,
    cap: usize,
) -> (Sender<T>, Vec<Receiver<T>>, JoinHandle<()>) {
    spmc_with(consumers, cap, WaitMode::Spin)
}

/// [`spmc`] with an explicit [`WaitMode`]: the arbiter (and the handed-
/// out endpoints) escalate idle waits to doorbell parks instead of
/// spinning forever.
pub fn spmc_with<T: Send + 'static>(
    consumers: usize,
    cap: usize,
    mode: WaitMode,
) -> (Sender<T>, Vec<Receiver<T>>, JoinHandle<()>) {
    assert!(consumers >= 1);
    let wait = WaitCfg {
        mode,
        ..WaitCfg::spin()
    };
    let (mut tx_in, mut rx_in) = stream::<T>(cap);
    tx_in.set_wait(mode);
    rx_in.set_wait(mode);
    let mut outs = Vec::with_capacity(consumers);
    let mut rxs = Vec::with_capacity(consumers);
    for _ in 0..consumers {
        let (mut tx, mut rx) = stream::<T>(cap);
        tx.set_wait(mode);
        rx.set_wait(mode);
        outs.push(tx);
        rxs.push(rx);
    }
    let arbiter = std::thread::Builder::new()
        .name("ff-spmc-arbiter".into())
        .spawn(move || {
            let mut next = 0usize;
            loop {
                match rx_in.recv() {
                    Msg::Task(t) => {
                        if route_skip_full(&mut outs, &mut next, t, &wait).is_err() {
                            break; // every consumer gone: poison the producer
                        }
                    }
                    Msg::Batch(ts) => {
                        let dead = rx_in.recycle_after(ts, |ts| {
                            for t in ts.drain(..) {
                                if route_skip_full(&mut outs, &mut next, t, &wait).is_err() {
                                    return true;
                                }
                            }
                            false
                        });
                        if dead {
                            break;
                        }
                    }
                    Msg::Eos => break,
                }
            }
            for o in outs.iter_mut() {
                let _ = o.send_eos();
            }
        })
        .expect("spawn spmc arbiter");
    (tx_in, rxs, arbiter)
}

/// Many-to-one: `n` producers feed a single consumer through a Collector
/// arbiter. The consumer receives EOS once *all* producers sent EOS.
pub fn mpsc<T: Send + 'static>(
    producers: usize,
    cap: usize,
) -> (Vec<Sender<T>>, Receiver<T>, JoinHandle<()>) {
    mpsc_with(producers, cap, WaitMode::Spin)
}

/// [`mpsc`] with an explicit [`WaitMode`]: the merge arbiter parks on
/// any producer lane's data doorbell when every lane is empty.
pub fn mpsc_with<T: Send + 'static>(
    producers: usize,
    cap: usize,
    mode: WaitMode,
) -> (Vec<Sender<T>>, Receiver<T>, JoinHandle<()>) {
    assert!(producers >= 1);
    let wait = WaitCfg {
        mode,
        ..WaitCfg::spin()
    };
    let mut ins = Vec::with_capacity(producers);
    let mut rxs = Vec::with_capacity(producers);
    for _ in 0..producers {
        let (mut tx, mut rx) = stream::<T>(cap);
        tx.set_wait(mode);
        rx.set_wait(mode);
        ins.push(tx);
        rxs.push(rx);
    }
    let (mut tx_out, mut rx_out) = stream::<T>(cap);
    tx_out.set_wait(mode);
    rx_out.set_wait(mode);
    let arbiter = std::thread::Builder::new()
        .name("ff-mpsc-arbiter".into())
        .spawn(move || {
            let n = rxs.len();
            let mut eos = vec![false; n];
            let mut eos_count = 0;
            let mut backoff = Backoff::new();
            while eos_count < n {
                let mut progressed = false;
                for (i, rx) in rxs.iter_mut().enumerate() {
                    if eos[i] {
                        continue;
                    }
                    match rx.try_recv() {
                        Some(Msg::Task(t)) => {
                            progressed = true;
                            if tx_out.send(t).is_err() {
                                return;
                            }
                        }
                        Some(Msg::Batch(ts)) => {
                            // Forward the run as one frame: the merge
                            // keeps the batch's single-synchronization
                            // economy on the consumer side too. The run
                            // is re-framed into a buffer recycled on the
                            // *output* stream and the input buffer goes
                            // straight back to its own free lane.
                            progressed = true;
                            let run = tx_out.reframe(rx, ts);
                            if tx_out.send_batch(run).is_err() {
                                return;
                            }
                        }
                        Some(Msg::Eos) => {
                            progressed = true;
                            eos[i] = true;
                            eos_count += 1;
                        }
                        None => {
                            // dead producer without EOS ⇒ synthetic EOS
                            if !rx.peer_alive() && !rx.has_next() {
                                progressed = true;
                                eos[i] = true;
                                eos_count += 1;
                            }
                        }
                    }
                }
                if progressed {
                    backoff.reset();
                } else if wait.wants_park(&mut backoff) {
                    let bells: Vec<&Doorbell> = rxs.iter().map(|rx| rx.data_bell()).collect();
                    wait.park_any(&bells, || {
                        !rxs.iter().enumerate().any(|(i, rx)| {
                            !eos[i] && (rx.has_next() || !rx.peer_alive())
                        })
                    });
                } else {
                    backoff.snooze();
                }
            }
            let _ = tx_out.send_eos();
        })
        .expect("spawn mpsc arbiter");
    (ins, rx_out, arbiter)
}

/// Many-to-many: `p` producers, `c` consumers, one Collector-Emitter
/// arbiter in the middle (the paper's CE / master-worker plumbing).
pub fn mpmc<T: Send + 'static>(
    producers: usize,
    consumers: usize,
    cap: usize,
) -> (Vec<Sender<T>>, Vec<Receiver<T>>, JoinHandle<()>) {
    mpmc_with(producers, consumers, cap, WaitMode::Spin)
}

/// [`mpmc`] with an explicit [`WaitMode`] for the CE arbiter and the
/// handed-out endpoints.
pub fn mpmc_with<T: Send + 'static>(
    producers: usize,
    consumers: usize,
    cap: usize,
    mode: WaitMode,
) -> (Vec<Sender<T>>, Vec<Receiver<T>>, JoinHandle<()>) {
    assert!(producers >= 1 && consumers >= 1);
    let wait = WaitCfg {
        mode,
        ..WaitCfg::spin()
    };
    let mut ins = Vec::with_capacity(producers);
    let mut in_rxs = Vec::with_capacity(producers);
    for _ in 0..producers {
        let (mut tx, mut rx) = stream::<T>(cap);
        tx.set_wait(mode);
        rx.set_wait(mode);
        ins.push(tx);
        in_rxs.push(rx);
    }
    let mut outs = Vec::with_capacity(consumers);
    let mut out_rxs = Vec::with_capacity(consumers);
    for _ in 0..consumers {
        let (mut tx, mut rx) = stream::<T>(cap);
        tx.set_wait(mode);
        rx.set_wait(mode);
        outs.push(tx);
        out_rxs.push(rx);
    }
    let arbiter = std::thread::Builder::new()
        .name("ff-mpmc-arbiter".into())
        .spawn(move || {
            let np = in_rxs.len();
            let mut eos = vec![false; np];
            let mut eos_count = 0;
            let mut next = 0usize;
            let mut backoff = Backoff::new();
            'cycle: while eos_count < np {
                let mut progressed = false;
                for i in 0..np {
                    if eos[i] {
                        continue;
                    }
                    match in_rxs[i].try_recv() {
                        Some(Msg::Task(t)) => {
                            progressed = true;
                            if route_skip_full(&mut outs, &mut next, t, &wait).is_err() {
                                break 'cycle; // all consumers gone
                            }
                        }
                        Some(Msg::Batch(ts)) => {
                            progressed = true;
                            let dead = in_rxs[i].recycle_after(ts, |ts| {
                                for t in ts.drain(..) {
                                    if route_skip_full(&mut outs, &mut next, t, &wait).is_err() {
                                        return true;
                                    }
                                }
                                false
                            });
                            if dead {
                                break 'cycle;
                            }
                        }
                        Some(Msg::Eos) => {
                            progressed = true;
                            eos[i] = true;
                            eos_count += 1;
                        }
                        None => {
                            // dead producer without EOS ⇒ synthetic EOS
                            if !in_rxs[i].peer_alive() && !in_rxs[i].has_next() {
                                progressed = true;
                                eos[i] = true;
                                eos_count += 1;
                            }
                        }
                    }
                }
                if progressed {
                    backoff.reset();
                } else if wait.wants_park(&mut backoff) {
                    let bells: Vec<&Doorbell> =
                        in_rxs.iter().map(|rx| rx.data_bell()).collect();
                    wait.park_any(&bells, || {
                        !in_rxs.iter().enumerate().any(|(i, rx)| {
                            !eos[i] && (rx.has_next() || !rx.peer_alive())
                        })
                    });
                } else {
                    backoff.snooze();
                }
            }
            for o in outs.iter_mut() {
                let _ = o.send_eos();
            }
        })
        .expect("spawn mpmc arbiter");
    (ins, out_rxs, arbiter)
}

/// Convenience: default capacity.
pub fn spmc_default<T: Send + 'static>(
    consumers: usize,
) -> (Sender<T>, Vec<Receiver<T>>, JoinHandle<()>) {
    spmc(consumers, DEFAULT_QUEUE_CAP)
}

/// Rebalance the **tails** of two lanes owned by the same producer
/// thread — the elastic pool's steal handle in standalone arbiter form:
/// revoke up to `max` published-but-undispatched frames from the back
/// of `from` (newest first, i.e. work its consumer has *not* yet
/// observed) and re-publish them on `to`.
///
/// Both lanes stay strictly SPSC: the caller holds `&mut` on both
/// senders, so the single-producer discipline is enforced at compile
/// time, and the only consumer-side cooperation needed is the stealable
/// ring's per-slot claim protocol ([`crate::spsc::spsc_stealable`] /
/// [`crate::channel::stream_stealable`]). Frames move whole (a batch is
/// never split, keeping its single-synchronization economy) and EOS is
/// never moved: a revoked close marker is pushed straight back and the
/// rebalance stops. Lanes over plain rings or unbounded streams refuse
/// to unsend *published* frames, so only their staged (multipush) tail
/// can move. A `to` lane that dies mid-move behaves like any send to a
/// dead lane: the frame is dropped with the send error.
///
/// Returns the number of frames moved.
pub fn rebalance_tail<T: Send>(from: &mut Sender<T>, to: &mut Sender<T>, max: usize) -> usize {
    let mut moved = 0usize;
    while moved < max && to.peer_alive() && !to.is_full() {
        match from.try_unsend() {
            None => break,
            Some(Msg::Eos) => {
                // Never move a close marker between lanes.
                let _ = from.send_eos();
                break;
            }
            Some(Msg::Task(t)) => {
                if to.send(t).is_err() {
                    break;
                }
                moved += 1;
            }
            Some(Msg::Batch(ts)) => {
                if to.send_batch(ts).is_err() {
                    break;
                }
                moved += 1;
            }
        }
    }
    moved
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a receiver to EOS, flattening any batch frames.
    fn drain_all<T: Send>(rx: &mut Receiver<T>) -> Vec<T> {
        let mut got = vec![];
        loop {
            match rx.recv() {
                Msg::Task(t) => got.push(t),
                Msg::Batch(ts) => got.extend(ts),
                Msg::Eos => break,
            }
        }
        got
    }

    #[test]
    fn spmc_distributes_everything() {
        let (mut tx, rxs, arbiter) = spmc::<u64>(3, 16);
        let consumers: Vec<_> = rxs
            .into_iter()
            .map(|mut rx| std::thread::spawn(move || drain_all(&mut rx)))
            .collect();
        for i in 0..3000u64 {
            tx.send(i).unwrap();
        }
        tx.send_eos().unwrap();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        arbiter.join().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..3000).collect::<Vec<_>>());
    }

    #[test]
    fn mpsc_merges_everything() {
        let (txs, mut rx, arbiter) = mpsc::<u64>(4, 16);
        let producers: Vec<_> = txs
            .into_iter()
            .enumerate()
            .map(|(p, mut tx)| {
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        tx.send(p as u64 * 1000 + i).unwrap();
                    }
                    tx.send_eos().unwrap();
                })
            })
            .collect();
        let mut got = drain_all(&mut rx);
        for h in producers {
            h.join().unwrap();
        }
        arbiter.join().unwrap();
        assert_eq!(got.len(), 2000);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 2000); // no duplication, no loss
    }

    #[test]
    fn mpsc_preserves_per_producer_order() {
        let (txs, mut rx, _arbiter) = mpsc::<(usize, u64)>(2, 8);
        let producers: Vec<_> = txs
            .into_iter()
            .enumerate()
            .map(|(p, mut tx)| {
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        tx.send((p, i)).unwrap();
                    }
                    tx.send_eos().unwrap();
                })
            })
            .collect();
        let mut last = vec![-1i64; 2];
        for (p, i) in drain_all(&mut rx) {
            assert!(i as i64 > last[p], "order violated for producer {p}");
            last[p] = i as i64;
        }
        for h in producers {
            h.join().unwrap();
        }
    }

    #[test]
    fn mpmc_routes_all() {
        let (txs, rxs, arbiter) = mpmc::<u64>(2, 2, 8);
        let producers: Vec<_> = txs
            .into_iter()
            .enumerate()
            .map(|(p, mut tx)| {
                std::thread::spawn(move || {
                    for i in 0..400u64 {
                        tx.send(p as u64 * 1000 + i).unwrap();
                    }
                    tx.send_eos().unwrap();
                })
            })
            .collect();
        let consumers: Vec<_> = rxs
            .into_iter()
            .map(|mut rx| std::thread::spawn(move || drain_all(&mut rx)))
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        arbiter.join().unwrap();
        all.sort_unstable();
        assert_eq!(all.len(), 800);
        all.dedup();
        assert_eq!(all.len(), 800);
    }

    #[test]
    fn park_mode_arbiters_conserve_messages() {
        // The doorbell-parking arbiters must behave exactly like the
        // spinning ones: nothing lost, nothing duplicated, EOS fans out.
        let (mut tx, rxs, arbiter) = spmc_with::<u64>(3, 8, crate::util::WaitMode::Park);
        let consumers: Vec<_> = rxs
            .into_iter()
            .map(|mut rx| {
                std::thread::spawn(move || {
                    // Slow consumers force the producer + arbiter to park.
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    drain_all(&mut rx)
                })
            })
            .collect();
        for i in 0..900u64 {
            tx.send(i).unwrap();
        }
        tx.send_eos().unwrap();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        arbiter.join().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..900).collect::<Vec<_>>());

        let (txs, mut rx, arbiter) = mpsc_with::<u64>(2, 8, crate::util::WaitMode::Park);
        let producers: Vec<_> = txs
            .into_iter()
            .enumerate()
            .map(|(p, mut tx)| {
                std::thread::spawn(move || {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    for i in 0..400u64 {
                        tx.send(p as u64 * 1000 + i).unwrap();
                    }
                    tx.send_eos().unwrap();
                })
            })
            .collect();
        let mut got = drain_all(&mut rx);
        for h in producers {
            h.join().unwrap();
        }
        arbiter.join().unwrap();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 800);
    }

    #[test]
    fn spmc_all_consumers_gone_poisons_producer() {
        // Regression: with every consumer dropped, route_skip_full used
        // to spin forever on the first full dead queue (and silently
        // swallow frames into dead rings with spare slots). Now dead
        // consumers leave the rotation and the arbiter exits, so the
        // producer's stream reports disconnection.
        let (mut tx, rxs, arbiter) = spmc::<u64>(3, 2);
        drop(rxs);
        let mut saw_disconnect = false;
        for i in 0..100_000u64 {
            if tx.send(i).is_err() {
                saw_disconnect = true;
                break;
            }
        }
        assert!(saw_disconnect, "producer must observe the poisoned stream");
        arbiter.join().unwrap();
    }

    #[test]
    fn mpmc_all_consumers_gone_terminates_arbiter() {
        let (mut txs, out_rxs, arbiter) = mpmc::<u64>(2, 2, 2);
        drop(out_rxs);
        for tx in txs.iter_mut() {
            // Batched and plain sends both hit the dead-rotation path.
            let _ = tx.send_batch(vec![1, 2, 3]);
            for i in 0..100_000u64 {
                if tx.send(i).is_err() {
                    break;
                }
            }
        }
        drop(txs);
        arbiter.join().unwrap(); // must not hang
    }

    #[test]
    fn rebalance_tail_moves_published_frames() {
        use crate::channel::stream_stealable;
        let (mut a_tx, mut a_rx) = stream_stealable::<u64>(16);
        let (mut b_tx, mut b_rx) = stream_stealable::<u64>(16);
        for i in 0..6u64 {
            a_tx.send(i).unwrap();
        }
        // Tail steal is newest-first: 5 then 4 move, 0..=3 stay put.
        let moved = rebalance_tail(&mut a_tx, &mut b_tx, 2);
        assert_eq!(moved, 2);
        a_tx.send_eos().unwrap();
        b_tx.send_eos().unwrap();
        assert_eq!(drain_all(&mut a_rx), vec![0, 1, 2, 3]);
        assert_eq!(drain_all(&mut b_rx), vec![5, 4]);
    }

    #[test]
    fn rebalance_tail_never_moves_eos() {
        use crate::channel::stream_stealable;
        let (mut a_tx, mut a_rx) = stream_stealable::<u64>(8);
        let (mut b_tx, mut b_rx) = stream_stealable::<u64>(8);
        a_tx.send(1).unwrap();
        a_tx.send_eos().unwrap();
        // The newest frame is the close marker: it must bounce back,
        // terminating the rebalance with nothing moved.
        let moved = rebalance_tail(&mut a_tx, &mut b_tx, 4);
        assert_eq!(moved, 0);
        b_tx.send_eos().unwrap();
        assert_eq!(drain_all(&mut a_rx), vec![1]);
        assert_eq!(drain_all(&mut b_rx), Vec::<u64>::new());
    }

    #[test]
    fn rebalance_tail_respects_plain_rings() {
        // Published frames on a non-stealable ring are out of reach —
        // the helper must move nothing rather than corrupt the queue.
        let (mut a_tx, mut a_rx) = stream::<u64>(8);
        let (mut b_tx, mut b_rx) = stream_stealable::<u64>(8);
        for i in 0..4u64 {
            a_tx.send(i).unwrap();
        }
        assert_eq!(rebalance_tail(&mut a_tx, &mut b_tx, 4), 0);
        a_tx.send_eos().unwrap();
        b_tx.send_eos().unwrap();
        assert_eq!(drain_all(&mut a_rx), vec![0, 1, 2, 3]);
        assert_eq!(drain_all(&mut b_rx), Vec::<u64>::new());
    }

    #[test]
    fn mpsc_reframes_batches_through_recycled_buffers() {
        // The merge arbiter re-frames each batch into an output-stream
        // buffer; once the consumer recycles, the arbiter's take_buf
        // draws recycled and its input buffers flow back to the senders.
        let (mut txs, mut rx, arbiter) = mpsc::<u64>(1, 8);
        for round in 0..20u64 {
            let mut buf = txs[0].take_buf();
            buf.extend(round * 10..round * 10 + 5);
            txs[0].send_batch(buf).unwrap();
            match rx.recv() {
                Msg::Batch(mut vs) => {
                    assert_eq!(vs.len(), 5);
                    vs.drain(..);
                    rx.recycle(vs);
                }
                other => panic!("expected batch, got {other:?}"),
            }
        }
        // The client's free lane is fed by the arbiter: after warmup the
        // sender stops allocating fresh buffers.
        assert!(
            txs[0].batch_reused() > 0,
            "sender must see recycled buffers back from the arbiter"
        );
        for mut tx in txs {
            tx.send_eos().unwrap();
        }
        assert_eq!(rx.recv(), Msg::Eos);
        arbiter.join().unwrap();
    }

    #[test]
    fn spmc_unpacks_batches_mpsc_preserves_them() {
        // SPMC: a batch is spread over consumers as individual tasks.
        let (mut tx, rxs, arbiter) = spmc::<u64>(2, 8);
        let consumers: Vec<_> = rxs
            .into_iter()
            .map(|mut rx| std::thread::spawn(move || drain_all(&mut rx)))
            .collect();
        tx.send_batch((0..100).collect()).unwrap();
        tx.send_eos().unwrap();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        arbiter.join().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());

        // MPSC: the merged stream conserves batched items.
        let (mut txs, mut rx, arbiter) = mpsc::<u64>(2, 8);
        txs[0].send_batch((0..50).collect()).unwrap();
        txs[1].send_batch((50..100).collect()).unwrap();
        for mut tx in txs {
            tx.send_eos().unwrap();
        }
        let mut got = drain_all(&mut rx);
        arbiter.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }
}
