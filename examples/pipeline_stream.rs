//! Stream-parallel pipeline example (paper §2.4): a three-stage text
//! analytics pipeline with a farm nested in the middle — the skeleton
//! composition the paper contrasts with TBB's linear-only `pipeline`.
//!
//! stage 1 (node):  tokenize lines into words
//! stage 2 (farm):  per-word "heavy" feature hash (functional replication)
//! stage 3 (node):  running top-K by hash score
//!
//! ```text
//! cargo run --release --example pipeline_stream -- [lines] [workers]
//! ```

use fastflow::prelude::*;
use fastflow::util::{fmt_duration, num_cpus, timed, XorShift64};

/// Stage 1: split a line into words (multi-emission node).
struct Tokenizer;
impl Node for Tokenizer {
    type In = String;
    type Out = String;
    fn svc(&mut self, line: String, out: &mut Outbox<'_, String>) -> Svc {
        for w in line.split_whitespace() {
            out.send(w.to_string());
        }
        Svc::GoOn
    }
}

/// A deliberately-heavy word feature: iterated FNV over the bytes.
fn heavy_hash(word: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for _round in 0..2_000 {
        for b in word.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let lines: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2_000);
    let workers: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| num_cpus().max(2) - 1);

    // Synthesize a deterministic corpus.
    let vocab = [
        "stream", "farm", "pipeline", "skeleton", "lockfree", "queue", "offload", "core",
        "accelerator", "fastflow",
    ];
    let mut rng = XorShift64::new(42);
    let corpus: Vec<String> = (0..lines)
        .map(|_| {
            let n = 3 + rng.next_below(8) as usize;
            (0..n)
                .map(|_| vocab[rng.next_below(vocab.len() as u64) as usize])
                .collect::<Vec<_>>()
                .join(" ")
        })
        .collect();
    let total_words: usize = corpus.iter().map(|l| l.split_whitespace().count()).sum();

    // Sequential baseline.
    let (seq_max, t_seq) = timed(|| {
        corpus
            .iter()
            .flat_map(|l| l.split_whitespace())
            .map(heavy_hash)
            .max()
            .unwrap()
    });

    // Pipeline: tokenizer → farm(hash) → max-reduce, wrapped as an
    // accelerator — one combinator chain, one launch path.
    let mut acc: Accel<String, u64> = seq(Tokenizer)
        .then(farm(FarmConfig::default().workers(workers), |_| {
            seq_fn(|w: String| heavy_hash(&w))
        }))
        .then(seq_fn(|h: u64| h))
        .into_accel();

    let (par_max, t_par) = timed(|| {
        for line in &corpus {
            acc.offload(line.clone()).expect("offload");
        }
        acc.offload_eos();
        let mut best = 0u64;
        let mut count = 0usize;
        while let Some(h) = acc.load_result() {
            best = best.max(h);
            count += 1;
        }
        assert_eq!(count, total_words, "every word must be processed once");
        best
    });
    acc.wait();

    println!(
        "pipeline_stream: {lines} lines / {total_words} words | seq {} | pipeline({workers}w) {} | speedup {:.2}",
        fmt_duration(t_seq),
        fmt_duration(t_par),
        t_seq.as_secs_f64() / t_par.as_secs_f64()
    );
    assert_eq!(seq_max, par_max, "reduction result must match");
    println!("verified: pipeline max == sequential max ({par_max:#x})");
}
