//! Divide & conquer on the accelerator (paper §2.4 "farm-with-feedback
//! (i.e. Divide&Conquer)"): quicksort where partition tasks are offloaded
//! to the farm and the *feedback* path runs through the offloading
//! thread — each worker either sorts a small range in place or splits it
//! and returns the halves, which the caller re-offloads. The caller
//! tracks in-flight tasks and closes the stream when the recursion tree
//! is exhausted (the termination protocol §3.1 leaves to the programmer).

use std::sync::Arc;

use fastflow::prelude::*;
use fastflow::util::{fmt_duration, num_cpus, timed, XorShift64};

/// A sortable range of the shared buffer. The buffer is shared mutable
/// state; correctness follows the paper's Bernstein discipline: ranges in
/// flight are disjoint by construction of quicksort's recursion.
#[derive(Clone, Copy, Debug)]
struct RangeTask {
    lo: usize,
    hi: usize, // exclusive
}

/// Worker result: either "sorted in place" or "split at p".
#[derive(Clone, Copy, Debug)]
enum Done {
    Sorted,
    Split(usize, RangeTask, RangeTask),
}

struct SharedBuf(std::cell::UnsafeCell<Vec<u64>>);
// SAFETY: disjoint ranges (see RangeTask docs); caller reads only after
// the EOS barrier.
unsafe impl Sync for SharedBuf {}
unsafe impl Send for SharedBuf {}

const CUTOFF: usize = 2_048;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2_000_000);
    let workers: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| num_cpus().max(2) - 1);

    let mut rng = XorShift64::new(9);
    let data: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();

    // Sequential baseline.
    let mut seq = data.clone();
    let (_, t_seq) = timed(|| seq.sort_unstable());

    // Accelerated D&C.
    let buf = Arc::new(SharedBuf(std::cell::UnsafeCell::new(data)));
    let b2 = buf.clone();
    let mut acc: FarmAccel<RangeTask, Done> = farm(
        FarmConfig::default()
            .workers(workers)
            .sched(SchedPolicy::OnDemand),
        move |_| {
            let buf = b2.clone();
            seq_fn(move |t: RangeTask| {
                // SAFETY: ranges in flight are disjoint.
                let v = unsafe { &mut *buf.0.get() };
                let slice = &mut v[t.lo..t.hi];
                if slice.len() <= CUTOFF {
                    slice.sort_unstable();
                    Done::Sorted
                } else {
                    // Hoare-ish partition around a median-of-3 pivot.
                    let pivot = median3(slice);
                    let mid = partition(slice, pivot);
                    // guard against degenerate splits
                    let mid = mid.clamp(1, slice.len() - 1);
                    Done::Split(
                        t.lo + mid,
                        RangeTask {
                            lo: t.lo,
                            hi: t.lo + mid,
                        },
                        RangeTask {
                            lo: t.lo + mid,
                            hi: t.hi,
                        },
                    )
                }
            })
        },
    )
    .into_accel();

    let (_, t_par) = timed(|| {
        // Feedback loop through the offloading thread. Deadlock-freedom:
        // never block on offload while results are undrained — pending
        // tasks wait in a local stack when the input channel is full.
        let mut pending = vec![RangeTask { lo: 0, hi: n }];
        let mut inflight = 0u64;
        while inflight > 0 || !pending.is_empty() {
            while let Some(t) = pending.pop() {
                match acc.try_offload(t) {
                    Ok(()) => inflight += 1,
                    Err((t, _)) => {
                        pending.push(t);
                        break;
                    }
                }
            }
            if inflight > 0 {
                match acc.load_result().expect("stream open while tasks in flight") {
                    Done::Sorted => inflight -= 1,
                    Done::Split(_, l, r) => {
                        inflight -= 1; // split task consumed…
                        pending.push(l); // …replaced by its halves
                        pending.push(r);
                    }
                }
            }
        }
        acc.offload_eos();
    });
    acc.wait();

    let sorted = Arc::try_unwrap(buf)
        .unwrap_or_else(|_| panic!("buffer still shared"))
        .0
        .into_inner();
    assert_eq!(sorted, seq, "parallel quicksort result mismatch");
    println!(
        "divide_conquer quicksort: {n} u64s | seq sort {} | D&C farm({workers}w) {} | speedup {:.2} [verified]",
        fmt_duration(t_seq),
        fmt_duration(t_par),
        t_seq.as_secs_f64() / t_par.as_secs_f64()
    );
}

fn median3(s: &[u64]) -> u64 {
    let a = s[0];
    let b = s[s.len() / 2];
    let c = s[s.len() - 1];
    a.max(b.min(c)).min(b.max(c))
}

/// Partition `s` so that elements < pivot precede the returned index.
fn partition(s: &mut [u64], pivot: u64) -> usize {
    let mut i = 0usize;
    let mut j = s.len();
    loop {
        while i < j && s[i] < pivot {
            i += 1;
        }
        while j > i && s[j - 1] >= pivot {
            j -= 1;
        }
        if i + 1 >= j {
            return i;
        }
        s.swap(i, j - 1);
        i += 1;
        j -= 1;
    }
}
