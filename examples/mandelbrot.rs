//! End-to-end driver (the required full-stack example): the QT-Mandelbrot
//! workload rendered progressively by the farm accelerator, optionally
//! executing each row tile through the AOT-compiled JAX/Pallas kernel via
//! PJRT (`--engine pjrt`), proving L3 (rust skeletons) ∘ L2 (jax graph) ∘
//! L1 (pallas kernel) compose. Writes a PGM image and prints the per-pass
//! timing table that EXPERIMENTS.md records.
//!
//! ```text
//! cargo run --release --example mandelbrot -- \
//!     [--region whole-set] [--width 640] [--height 480] [--passes 4] \
//!     [--workers N] [--engine scalar|pjrt] [--out mandel.pgm] [--quick]
//! ```

use fastflow::apps::mandelbrot::{
    max_iter_for_pass, render_sequential, AcceleratedRenderer, Engine, Region, RenderParams,
};
use fastflow::cli::Args;
use fastflow::metrics::Table;
use fastflow::runtime::MandelTileKernel;
use fastflow::util::{fmt_duration, num_cpus, timed};

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let quick = args.has_flag("quick");
    let region = args
        .get("region")
        .and_then(Region::by_name)
        .unwrap_or(Region::presets()[0]);
    let width = args.get_usize("width", if quick { 256 } else { 640 });
    let height = args.get_usize("height", if quick { 192 } else { 480 });
    let passes = args.get_u32("passes", if quick { 2 } else { 4 });
    let workers = args.get_usize("workers", num_cpus().max(2) - 1);
    let engine = match args.get("engine") {
        Some("pjrt") => Engine::Pjrt,
        _ => Engine::Scalar,
    };
    if engine == Engine::Pjrt && !MandelTileKernel::available() {
        eprintln!("--engine pjrt requires a `--features pjrt` build and `make artifacts`");
        std::process::exit(1);
    }

    println!(
        "mandelbrot: region={} {}x{} passes={} workers={} engine={:?}",
        region.name, width, height, passes, workers, engine
    );

    let params = RenderParams {
        region,
        width,
        height,
    };
    let mut table = Table::new(&["pass", "max_iter", "seq-time", "ff-time", "speedup", "match"]);
    let mut renderer = AcceleratedRenderer::new(params, workers, engine);
    let mut last_frame = None;
    for pass in 0..passes {
        let max_iter = max_iter_for_pass(pass);
        let (seq, t_seq) = timed(|| {
            render_sequential(&region, width, height, max_iter, None).expect("no abort")
        });
        let (frame, t_ff) = timed(|| renderer.render_pass(max_iter, None).expect("no abort"));
        // PJRT runs in f32; allow tiny count differences at the boundary.
        let matches = if engine == Engine::Scalar {
            frame.iters == seq.iters
        } else {
            let diff = frame
                .iters
                .iter()
                .zip(&seq.iters)
                .filter(|(a, b)| a != b)
                .count();
            (diff as f64) < 0.02 * frame.iters.len() as f64
        };
        table.row(vec![
            pass.to_string(),
            max_iter.to_string(),
            fmt_duration(t_seq),
            fmt_duration(t_ff),
            format!("{:.2}", t_seq.as_secs_f64() / t_ff.as_secs_f64()),
            matches.to_string(),
        ]);
        last_frame = Some(frame);
    }
    let report = renderer.shutdown();
    print!("{}", table.render());
    if args.has_flag("trace") {
        print!("{}", report.render());
    }

    let out = args.get("out").unwrap_or("mandelbrot.pgm");
    let frame = last_frame.expect("passes >= 1");
    std::fs::write(out, frame.to_pgm()).expect("write pgm");
    println!(
        "wrote {out} (interior fraction {:.1}%)",
        frame.interior_fraction() * 100.0
    );
}
