//! The paper's §4.2 experiment as a runnable example: count N-queens
//! solutions sequentially (Somers-style bitboard) and with the
//! collector-less farm accelerator, verifying against OEIS A000170.
//!
//! ```text
//! cargo run --release --example nqueens -- [N] [depth] [workers]
//! ```

use fastflow::apps::nqueens::{count_parallel, count_sequential, gen_tasks, known_solutions};
use fastflow::util::{fmt_duration, num_cpus, timed};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(12);
    let depth: u32 = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4)
        .clamp(1, n.saturating_sub(1).max(1));
    let workers: usize = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| 2 * num_cpus());

    println!("N-queens {n}x{n}, task depth {depth} ({} tasks), {workers} workers",
        gen_tasks(n, depth).len());

    let (seq, t_seq) = timed(|| count_sequential(n));
    println!("sequential: {seq} solutions in {}", fmt_duration(t_seq));

    let (run, t_par) = timed(|| count_parallel(n, depth, workers));
    println!(
        "accelerated: {} solutions in {} ({} tasks, speedup {:.2})",
        run.solutions,
        fmt_duration(t_par),
        run.tasks,
        t_seq.as_secs_f64() / t_par.as_secs_f64()
    );

    assert_eq!(seq, run.solutions, "parallel count differs from sequential!");
    match known_solutions(n) {
        Some(k) => {
            assert_eq!(seq, k, "count differs from OEIS A000170!");
            println!("verified against OEIS A000170 ✓");
        }
        None => println!("(no reference count available for N = {n})"),
    }
}
