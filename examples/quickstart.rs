//! Quickstart: the paper's Fig. 3 derivation, end to end.
//!
//! A sequential matrix multiplication is accelerated by offloading row
//! tasks onto a farm accelerator built on spare cores; the result is
//! verified against the sequential code. If `make artifacts` has been
//! run, the f32 XLA (JAX + Pallas via PJRT) kernel is also exercised and
//! cross-checked — the full three-layer stack in one example.
//!
//! ```text
//! cargo run --release --example quickstart [n] [workers]
//! ```

use fastflow::apps::matmul::{
    matmul_accelerated, matmul_pjrt_f32, matmul_ref_f32, matmul_sequential, Matrix, PJRT_N,
};
use fastflow::prelude::*;
use fastflow::runtime::MatmulKernel;
use fastflow::util::{fmt_duration, num_cpus, timed, XorShift64};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(256);
    let workers: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| num_cpus().max(2) - 1);

    println!("== Fig. 3: sequential → farm-accelerated matmul ==");
    let a = Matrix::random(n, 1);
    let b = Matrix::random(n, 2);

    // Left column of Fig. 3: the original code.
    let (c_seq, t_seq) = timed(|| matmul_sequential(&a, &b));
    println!("sequential   {n}x{n}: {}", fmt_duration(t_seq));

    // Right column: create accelerator, offload rows, EOS, wait.
    let (c_acc, t_acc) = timed(|| matmul_accelerated(&a, &b, workers));
    println!(
        "accelerated  {n}x{n}: {} ({workers} workers, speedup {:.2})",
        fmt_duration(t_acc),
        t_seq.as_secs_f64() / t_acc.as_secs_f64()
    );
    assert_eq!(c_seq, c_acc, "results must be identical");
    println!("verified: accelerated result == sequential result");

    // == Migration: Accel → AccelHandle (the multi-client service) ==
    //
    // The single-client session:
    //     let mut acc = farm(cfg, |_| seq(worker())).into_accel();  // 1:1 device
    //     acc.offload(t)?; … acc.load_result();
    // becomes, in two lines, a device shared by any number of threads:
    //     let (mut pool, h) = AccelPool::run(PoolConfig::default().farm(cfg),
    //                                        |_shard, _w| worker());
    //     h.offload(t)?; … pool.load_result();   // h.clone() per extra client
    // (shards can be whole composed skeletons too: AccelPool::run_skeleton)
    println!("\n== AccelPool: the same device, shared by 4 client threads ==");
    let (mut pool, root) = AccelPool::run(
        PoolConfig::default()
            .shards(2)
            .batch(32)
            .farm(FarmConfig::default().workers(workers.max(2) / 2)),
        |_shard, _w| node_fn(|x: u64| x * x),
    );
    let per_client = 25_000u64;
    let offloaders: Vec<_> = (0..4u64)
        .map(|c| {
            let mut h = root.clone(); // a clone is a new client lane
            std::thread::spawn(move || {
                for i in 0..per_client {
                    h.offload(c * per_client + i).expect("offload");
                }
                h.finish().expect("finish");
            })
        })
        .collect();
    drop(root);
    pool.offload_eos();
    let mut sum = 0u64;
    let mut count = 0u64;
    while let Some(sq) = pool.load_result() {
        sum = sum.wrapping_add(sq);
        count += 1;
    }
    for j in offloaders {
        j.join().expect("client thread");
    }
    pool.wait();
    let expect: u64 = (0..4 * per_client).map(|i| i.wrapping_mul(i)).fold(0, u64::wrapping_add);
    assert_eq!(count, 4 * per_client);
    assert_eq!(sum, expect, "pooled result set must equal sequential");
    println!("verified: 4 clients × {per_client} tasks through 2 shards == sequential sums");

    // Three-layer path: the same computation AOT-compiled from JAX/Pallas.
    if MatmulKernel::available() {
        let mut rng = XorShift64::new(3);
        let a32: Vec<f32> = (0..PJRT_N * PJRT_N)
            .map(|_| (rng.next_u64() % 1000) as f32 / 500.0 - 1.0)
            .collect();
        let b32: Vec<f32> = (0..PJRT_N * PJRT_N)
            .map(|_| (rng.next_u64() % 1000) as f32 / 500.0 - 1.0)
            .collect();
        let (c32, t32) = timed(|| matmul_pjrt_f32(&a32, &b32).expect("pjrt matmul"));
        let reference = matmul_ref_f32(&a32, &b32, PJRT_N);
        let max_err = c32
            .iter()
            .zip(&reference)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        println!(
            "pjrt kernel  {PJRT_N}x{PJRT_N}: {} (max |err| vs rust ref = {max_err:.2e})",
            fmt_duration(t32)
        );
        assert!(max_err < 1e-3, "PJRT kernel numerically diverged");
        println!("verified: AOT JAX/Pallas kernel matches the Rust reference");
    } else {
        println!(
            "pjrt kernel: unavailable — build with `--features pjrt` and run \
             `make artifacts` to exercise L1/L2"
        );
    }
}
