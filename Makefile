# FastFlow accelerator reproduction — build entry points.
#
# `make artifacts` is the only step that runs Python (JAX/Pallas): it
# AOT-compiles the numeric kernels to HLO text under artifacts/, which
# the Rust side (built with `--features pjrt`) loads at start-up via
# PJRT. Everything else is plain cargo.

CARGO  ?= cargo
PYTHON ?= python
ARTIFACT_DIR ?= artifacts

.PHONY: all build test test-fallback test-oversub bench bench-smoke bench-diff bench-baseline serve net-smoke doc artifacts fmt clippy audit lint loom miri tsan pytest clean

# The quick-mode benches that feed the committed perf wall (bench/).
BENCH_SMOKE_SET = accel_multiclient nested_topologies allocator queue_latency placement steal

all: build

build:
	cd rust && $(CARGO) build --release

# Tier-1 verification: must stay green with no XLA libraries installed
# and no artifacts built (PJRT-dependent tests skip, never fail).
test:
	cd rust && $(CARGO) build --release && $(CARGO) test -q

# The no-default-features lane: proves the fallback kernel path
# (scoped: identical to `test` while `default = []`, but guards the
# zero-dep path if a default feature ever appears).
test-fallback:
	cd rust && $(CARGO) test -q --no-default-features --lib --test fallback_kernel

# Over-subscription smoke lane: the Park-mode waiting suite plus the
# elastic-pool suite, both with workers ≫ cores (includes the
# #[ignore]d heavy cases CI also runs).
test-oversub:
	cd rust && $(CARGO) test -q --test waiting -- --include-ignored
	cd rust && $(CARGO) test -q --test elastic -- --include-ignored

bench:
	cd rust && $(CARGO) bench --bench fig4_mandelbrot -- --quick
	cd rust && $(CARGO) bench --bench table2_nqueens -- --quick

# CI smoke lane: compile every bench, then run the quick sweeps in
# $(BENCH_SMOKE_SET), writing $(ARTIFACT_DIR)/BENCH_*.json (the
# machine-readable perf trajectory benchkit emits via FF_BENCH_JSON)
# and diffing each report against the committed wall in bench/
# (FF_BENCH_BASELINE — advisory here: regressions print `bench-diff:`
# lines but never fail; see bench-diff for the blocking form).
bench-smoke:
	cd rust && $(CARGO) bench --no-run
	cd rust && for b in $(BENCH_SMOKE_SET); do \
		FF_BENCH_SAMPLES=2 FF_BENCH_WARMUP=0 \
		FF_BENCH_JSON=$(abspath $(ARTIFACT_DIR)) \
		FF_BENCH_BASELINE=$(abspath bench) \
		$(CARGO) bench --bench $$b -- --quick || exit 1; \
	done
	cd rust && FF_BENCH_SAMPLES=2 FF_BENCH_WARMUP=0 \
		FF_BENCH_JSON=$(abspath $(ARTIFACT_DIR)) \
		FF_BENCH_BASELINE=$(abspath bench) \
		$(CARGO) run --release --bin ffctl -- netbench --quick

# The blocking perf gate (self-hosted perf runners, or local checks on
# a quiet machine): same quick sweeps, but any regression beyond
# FF_BENCH_TOLERANCE (default ±30%) vs the committed bench/ baselines
# fails the target.
bench-diff:
	cd rust && for b in $(BENCH_SMOKE_SET); do \
		FF_BENCH_SAMPLES=2 FF_BENCH_WARMUP=0 \
		FF_BENCH_BASELINE=$(abspath bench) FF_BENCH_STRICT=1 \
		$(CARGO) bench --bench $$b -- --quick || exit 1; \
	done
	cd rust && FF_BENCH_SAMPLES=2 FF_BENCH_WARMUP=0 \
		FF_BENCH_BASELINE=$(abspath bench) FF_BENCH_STRICT=1 \
		$(CARGO) run --release --bin ffctl -- netbench --quick

# Move the wall: regenerate the committed baselines in bench/ (run on a
# quiet machine, then commit the changed JSONs with the PR that
# justifies them — see bench/README.md).
bench-baseline:
	cd rust && for b in $(BENCH_SMOKE_SET); do \
		FF_BENCH_SAMPLES=2 FF_BENCH_WARMUP=0 \
		FF_BENCH_JSON=$(abspath bench) \
		$(CARGO) bench --bench $$b -- --quick || exit 1; \
	done
	cd rust && FF_BENCH_SAMPLES=2 FF_BENCH_WARMUP=0 \
		FF_BENCH_JSON=$(abspath bench) \
		$(CARGO) run --release --bin ffctl -- netbench --quick

# Run the accelerator as a TCP service (ffnet/1). Override knobs with
# SERVE_ARGS, e.g. `make serve SERVE_ARGS="--payload 512 --window 256"`.
SERVE_ARGS ?= --addr 127.0.0.1:7143 --payload 64
serve:
	cd rust && $(CARGO) run --release --bin ffctl -- serve $(SERVE_ARGS)

# Loopback net lane: the self-hosted netbench quick sweep (each payload
# size gets its own in-process server on port 0), emitting
# $(ARTIFACT_DIR)/BENCH_net.json and diffing it (advisory) against the
# committed bench/BENCH_net.json wall.
net-smoke:
	cd rust && FF_BENCH_SAMPLES=2 FF_BENCH_WARMUP=0 \
		FF_BENCH_JSON=$(abspath $(ARTIFACT_DIR)) \
		FF_BENCH_BASELINE=$(abspath bench) \
		$(CARGO) run --release --bin ffctl -- netbench --quick

# API docs with rustdoc warnings denied (deprecation shims must stay
# documented; broken intra-doc links fail the build).
doc:
	cd rust && RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# AOT-compile the JAX/Pallas kernels to HLO text (build-time only;
# Python never runs at request time).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir $(abspath $(ARTIFACT_DIR))

fmt:
	cd rust && $(CARGO) fmt --check

clippy:
	cd rust && $(CARGO) clippy --all-targets -- -D warnings

# The enforced domain-invariant pass (rust/tools/ffaudit): R1 facade,
# R2 SAFETY, R3 ordering tags, R4 loom coverage, R5 recycling, R6
# endpoint uniqueness — scanned statically over rust/src. Exits
# non-zero on any finding (the committed allowlist target is empty)
# and writes the machine-readable report to $(ARTIFACT_DIR)/audit.json.
audit:
	$(CARGO) run --release -p ffaudit -- --json $(ARTIFACT_DIR)/audit.json

# The blocking static-analysis gate CI runs: format + clippy wall +
# the ffaudit invariant pass.
lint: fmt clippy audit

# Model-check the lock-free core (bounded/unbounded SPSC, multipush,
# doorbell handshake, batch pool, stream framing) under loom: the
# `sync` facade swaps std atomics/threads/cells for loom's doubles, and
# every model in rust/tests/loom/ is explored with a preemption bound
# of 3 (see EXPERIMENTS.md §Verification for why that bound).
loom:
	cd rust && RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
		$(CARGO) test --release --test loom

# Run the concurrency-bearing unit tests under Miri (nightly). Strict
# provenance covers the raw-pointer queues (spsc::ptr, uSWSR segment
# chain); -Zmiri-disable-isolation lets Instant::now()-based grace
# logic run. Heavy cross-thread volumes shrink via cfg(miri); wall-
# clock tests are #[cfg_attr(miri, ignore)]d.
miri:
	cd rust && MIRIFLAGS="-Zmiri-strict-provenance -Zmiri-disable-isolation" \
		$(CARGO) +nightly miri test --lib -q -- \
		spsc:: channel:: alloc:: util:: baseline::

# ThreadSanitizer lane (nightly + rust-src): rebuilds std with TSan and
# runs the library tests. Advisory — TSan models SeqCst fences
# imprecisely, so findings are triaged, not auto-blocking (the loom
# lane is the authoritative fence check).
tsan:
	cd rust && RUSTFLAGS="-Zsanitizer=thread" \
		$(CARGO) +nightly test -q --lib \
		-Zbuild-std --target x86_64-unknown-linux-gnu

pytest:
	$(PYTHON) -m pytest python/tests -q

clean:
	cd rust && $(CARGO) clean
	rm -rf $(ARTIFACT_DIR)
