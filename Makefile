# FastFlow accelerator reproduction — build entry points.
#
# `make artifacts` is the only step that runs Python (JAX/Pallas): it
# AOT-compiles the numeric kernels to HLO text under artifacts/, which
# the Rust side (built with `--features pjrt`) loads at start-up via
# PJRT. Everything else is plain cargo.

CARGO  ?= cargo
PYTHON ?= python
ARTIFACT_DIR ?= artifacts

.PHONY: all build test test-fallback bench artifacts fmt clippy pytest clean

all: build

build:
	cd rust && $(CARGO) build --release

# Tier-1 verification: must stay green with no XLA libraries installed
# and no artifacts built (PJRT-dependent tests skip, never fail).
test:
	cd rust && $(CARGO) build --release && $(CARGO) test -q

# The no-default-features lane: proves the fallback kernel path
# (scoped: identical to `test` while `default = []`, but guards the
# zero-dep path if a default feature ever appears).
test-fallback:
	cd rust && $(CARGO) test -q --no-default-features --lib --test fallback_kernel

bench:
	cd rust && $(CARGO) bench --bench fig4_mandelbrot -- --quick
	cd rust && $(CARGO) bench --bench table2_nqueens -- --quick

# AOT-compile the JAX/Pallas kernels to HLO text (build-time only;
# Python never runs at request time).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir $(abspath $(ARTIFACT_DIR))

fmt:
	cd rust && $(CARGO) fmt --check

clippy:
	cd rust && $(CARGO) clippy --all-targets -- -D warnings

pytest:
	$(PYTHON) -m pytest python/tests -q

clean:
	cd rust && $(CARGO) clean
	rm -rf $(ARTIFACT_DIR)
