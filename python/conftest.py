"""Make the `compile` package importable no matter where pytest is
invoked from (repo root in CI, `python/` locally)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
