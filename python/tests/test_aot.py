"""AOT artifact generation: the HLO text must exist, parse as HLO, and
declare the shapes the Rust runtime expects."""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts():
    return aot.lower_all()


def test_lower_all_produces_both(artifacts):
    assert set(artifacts) == {"mandelbrot_tile.hlo.txt", "matmul.hlo.txt"}
    for name, text in artifacts.items():
        assert len(text) > 100, name


def test_hlo_text_format(artifacts):
    for name, text in artifacts.items():
        assert text.lstrip().startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_mandel_hlo_signature(artifacts):
    text = artifacts["mandelbrot_tile.hlo.txt"]
    t = model.TILE
    assert f"f32[{t}]" in text
    assert "s32[1]" in text
    assert f"s32[{t}]" in text  # output counts


def test_matmul_hlo_signature(artifacts):
    text = artifacts["matmul.hlo.txt"]
    n = model.MATMUL_N
    assert f"f32[{n},{n}]" in text


def test_main_writes_files(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out-dir", str(tmp_path)]
    )
    aot.main()
    names = sorted(os.listdir(tmp_path))
    assert "mandelbrot_tile.hlo.txt" in names
    assert "matmul.hlo.txt" in names
    assert "manifest.txt" in names
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "jax" in manifest
