"""Kernel-vs-oracle correctness: the CORE L1 signal.

hypothesis sweeps coordinates, iteration budgets and matrix contents;
every Pallas kernel must match its pure-jnp oracle exactly (integer
counts) or to f32 tolerance (matmul).
"""

import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is a build-time-only dev dependency; skip the sweep (not
# fail collection) on images that ship jax but not hypothesis.
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import mandelbrot, matmul, ref

TILE = mandelbrot.TILE


def _tile_from(points):
    """Pad a point list up to a full tile by repeating the last point."""
    pts = list(points) or [(0.0, 0.0)]
    while len(pts) < TILE:
        pts.append(pts[-1])
    xs = jnp.asarray([p[0] for p in pts[:TILE]], jnp.float32)
    ys = jnp.asarray([p[1] for p in pts[:TILE]], jnp.float32)
    return xs, ys


# ------------------------------------------------------------- mandelbrot


def test_mandel_known_points():
    cx, cy = _tile_from([(0.0, 0.0), (2.0, 2.0), (-1.0, 0.0), (0.3, 0.5)])
    out = np.asarray(mandelbrot.mandel_tile(cx, cy, jnp.asarray([100], jnp.int32)))
    assert out[0] == 100  # origin: interior
    assert out[1] <= 1  # far outside: immediate escape
    assert out[2] == 100  # c = -1: interior (period 2)
    assert out.shape == (TILE,)


def test_mandel_matches_ref_grid():
    xs = np.linspace(-2.2, 1.2, 16)
    ys = np.linspace(-1.6, 1.6, 16)
    pts = [(x, y) for x in xs for y in ys]
    cx, cy = _tile_from(pts)
    mi = jnp.asarray([200], jnp.int32)
    got = np.asarray(mandelbrot.mandel_tile(cx, cy, mi))
    want = np.asarray(ref.mandel_ref(cx, cy, 200))
    np.testing.assert_array_equal(got, want)


def test_mandel_matches_scalar_oracle():
    pts = [(-0.75, 0.11), (0.0, 1.0), (-1.75, 0.0), (0.25, 0.0)]
    cx, cy = _tile_from(pts)
    got = np.asarray(mandelbrot.mandel_tile(cx, cy, jnp.asarray([64], jnp.int32)))
    for i, (x, y) in enumerate(pts):
        assert got[i] == ref.mandel_scalar_ref(np.float32(x), np.float32(y), 64), (x, y)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    max_iter=st.integers(1, 300),
)
def test_mandel_hypothesis_matches_ref(seed, max_iter):
    rng = np.random.default_rng(seed)
    cx = jnp.asarray(rng.uniform(-2.5, 1.5, TILE), jnp.float32)
    cy = jnp.asarray(rng.uniform(-2.0, 2.0, TILE), jnp.float32)
    mi = jnp.asarray([max_iter], jnp.int32)
    got = np.asarray(mandelbrot.mandel_tile(cx, cy, mi))
    want = np.asarray(ref.mandel_ref(cx, cy, max_iter))
    np.testing.assert_array_equal(got, want)
    assert got.min() >= 0 and got.max() <= max_iter


def test_mandel_zero_budget():
    cx, cy = _tile_from([(0.0, 0.0)])
    out = np.asarray(mandelbrot.mandel_tile(cx, cy, jnp.asarray([0], jnp.int32)))
    assert (out == 0).all()


def test_mandel_budget_monotone():
    """Counts are monotone in the iteration budget (progressive passes)."""
    rng = np.random.default_rng(7)
    cx = jnp.asarray(rng.uniform(-2.0, 1.0, TILE), jnp.float32)
    cy = jnp.asarray(rng.uniform(-1.5, 1.5, TILE), jnp.float32)
    prev = None
    for budget in [16, 64, 256]:
        out = np.asarray(mandelbrot.mandel_tile(cx, cy, jnp.asarray([budget], jnp.int32)))
        if prev is not None:
            assert (out >= prev).all()
        prev = out


# ----------------------------------------------------------------- matmul


def test_matmul_identity():
    n = matmul.N
    eye = jnp.eye(n, dtype=jnp.float32)
    a = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n) / 1000.0
    got = np.asarray(matmul.matmul(a, eye))
    np.testing.assert_allclose(got, np.asarray(a), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_matmul_hypothesis_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n = matmul.N
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    got = np.asarray(matmul.matmul(a, b))
    want = np.asarray(ref.matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_zero():
    n = matmul.N
    z = jnp.zeros((n, n), jnp.float32)
    got = np.asarray(matmul.matmul(z, z))
    assert (got == 0).all()


# ------------------------------------------------------------------ model


def test_model_shapes_match_runtime_contract():
    """The Rust runtime hard-codes these shapes (runtime/mod.rs)."""
    assert model.TILE == 256
    assert model.MATMUL_N == 128
    args = model.mandel_example_args()
    assert args[0].shape == (256,) and str(args[0].dtype) == "float32"
    assert args[2].shape == (1,) and str(args[2].dtype) == "int32"
    m_args = model.matmul_example_args()
    assert m_args[0].shape == (128, 128)


def test_model_entry_points_callable():
    cx = jnp.zeros((model.TILE,), jnp.float32)
    out = model.mandel_tile(cx, cx, jnp.asarray([3], jnp.int32))
    assert out.shape == (model.TILE,)
    a = jnp.zeros((model.MATMUL_N, model.MATMUL_N), jnp.float32)
    assert model.matmul(a, a).shape == (model.MATMUL_N, model.MATMUL_N)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
