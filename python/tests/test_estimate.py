"""The DESIGN.md §8 TPU estimates must stay consistent with the shipped
kernel shapes (so a TILE/BLOCK change forces a re-estimate)."""

from compile import estimate
from compile.kernels import mandelbrot, matmul


def test_mandel_estimate_fits_vmem():
    e = estimate.mandel_estimate()
    assert e.vmem_fraction < 0.01, "tile state must be far under VMEM"
    assert str(mandelbrot.TILE) in e.name


def test_mandel_is_compute_bound():
    e = estimate.mandel_estimate(max_iter=256)
    # escape iteration reads 12 B/lane and does thousands of flops/lane
    assert e.arithmetic_intensity > 100
    assert "VPU" in e.bound


def test_matmul_estimate_fits_vmem():
    e = estimate.matmul_estimate()
    assert e.vmem_bytes < estimate.VMEM_BYTES
    assert str(matmul.BLOCK) in e.name
    assert "MXU" in e.bound


def test_report_renders():
    for e in estimate.all_estimates():
        text = e.render()
        assert "VMEM" in text and "bound" in text


def test_main_prints(capsys):
    estimate.main()
    out = capsys.readouterr().out
    assert "mandelbrot" in out and "matmul" in out
