"""TPU performance *estimate* for the L1 kernels (DESIGN.md §8).

Pallas runs here under ``interpret=True`` (CPU), so real-TPU wallclock is
unavailable; per the repo's methodology, TPU viability is argued from
static resource analysis of the kernel structure:

* VMEM footprint of the live state per grid step (vs ~16 MB/core);
* arithmetic intensity (FLOPs per HBM byte) against a v4-like roofline
  (~275 TFLOP/s bf16 MXU, ~1.2 TB/s HBM; VPU ~4.9 TFLOP/s f32);
* which unit bounds the kernel (MXU / VPU / HBM).

Usage::

    python -m compile.estimate            # prints the report
"""

from dataclasses import dataclass

from compile.kernels import mandelbrot, matmul

# --- v4-ish machine model (order-of-magnitude; sources: public specs) ---
VMEM_BYTES = 16 * 2**20
HBM_BW = 1.2e12  # B/s
VPU_F32_FLOPS = 4.9e12  # f32 elementwise
MXU_BF16_FLOPS = 275e12


@dataclass
class Estimate:
    name: str
    vmem_bytes: int
    flops_per_invocation: float
    hbm_bytes_per_invocation: float
    bound: str
    notes: str

    @property
    def vmem_fraction(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_invocation / max(self.hbm_bytes_per_invocation, 1.0)

    def render(self) -> str:
        return (
            f"{self.name}:\n"
            f"  VMEM live state : {self.vmem_bytes / 1024:.1f} KB"
            f" ({self.vmem_fraction * 100:.2f}% of {VMEM_BYTES >> 20} MB)\n"
            f"  FLOPs/invocation: {self.flops_per_invocation:.3e}\n"
            f"  HBM bytes/invoc : {self.hbm_bytes_per_invocation:.3e}\n"
            f"  intensity       : {self.arithmetic_intensity:.1f} FLOP/B\n"
            f"  bound           : {self.bound}\n"
            f"  notes           : {self.notes}\n"
        )


def mandel_estimate(max_iter: int = 256) -> Estimate:
    """Escape-iteration kernel at the shipped TILE width."""
    t = mandelbrot.TILE
    # live vectors: cx, cy, zr, zi (f32) + count (i32) + active (i8 mask)
    vmem = t * (4 * 4 + 4 + 1)
    # per iteration per lane: 2 mul (zr2, zi2), 1 add+cmp, 2 mul + 2 add
    # for the update, ~3 selects ≈ 10 f32 ops
    flops = 10.0 * t * max_iter
    hbm = t * (4 + 4 + 4)  # cx, cy in; counts out
    # intensity = 10*max_iter/12 per byte — enormous ⇒ compute (VPU) bound
    return Estimate(
        name=f"mandelbrot tile (TILE={t}, max_iter={max_iter})",
        vmem_bytes=vmem,
        flops_per_invocation=flops,
        hbm_bytes_per_invocation=hbm,
        bound="VPU (elementwise masked FMA chain; MXU idle)",
        notes=(
            "single fused while_loop, no gather/scatter, no host sync per "
            "iteration; expected ≥80% VPU issue efficiency; worst-lane "
            "effect bounds useful work by the deepest pixel per tile "
            "(see EXPERIMENTS.md §Perf L1.1)"
        ),
    )


def matmul_estimate() -> Estimate:
    """Blocked matmul kernel at the shipped block size."""
    n, b = matmul.N, matmul.BLOCK
    # per grid step: A band (b×n) + B band (n×b) + C block (b×b), f32
    vmem = 4 * (b * n + n * b + b * b)
    grid = (n // b) ** 2
    flops = 2.0 * n * n * n  # whole multiplication
    # each band re-read per output block row/col
    hbm = 4.0 * grid * (b * n + n * b) + 4.0 * n * n
    return Estimate(
        name=f"matmul (N={n}, BLOCK={b})",
        vmem_bytes=vmem,
        flops_per_invocation=flops,
        hbm_bytes_per_invocation=hbm,
        bound="MXU (128x128 systolic contraction per block)",
        notes=(
            "bands fit VMEM with 2.4% headroom at BLOCK=64; standard "
            "jnp.dot lowering -> MXU; ≥70% utilisation expected at these "
            "shapes (small N keeps it latency- rather than BW-bound)"
        ),
    )


def all_estimates():
    return [mandel_estimate(), matmul_estimate()]


def main() -> None:
    print("TPU static estimates (machine model: v4-ish; see module doc)\n")
    for e in all_estimates():
        print(e.render())


if __name__ == "__main__":
    main()
