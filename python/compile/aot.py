"""AOT lowering: jax → stablehlo → XlaComputation → **HLO text**.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts

Produces ``mandelbrot_tile.hlo.txt`` and ``matmul.hlo.txt`` plus a
``manifest.txt`` recording shapes and versions.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    """Lower every model entry point; returns {artifact_name: hlo_text}."""
    artifacts = {}
    lowered = jax.jit(model.mandel_tile).lower(*model.mandel_example_args())
    artifacts["mandelbrot_tile.hlo.txt"] = to_hlo_text(lowered)
    lowered = jax.jit(model.matmul).lower(*model.matmul_example_args())
    artifacts["matmul.hlo.txt"] = to_hlo_text(lowered)
    return artifacts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
        help="directory to write artifacts into",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = lower_all()
    manifest = [
        f"jax {jax.__version__}",
        f"mandel TILE={model.TILE}",
        f"matmul N={model.MATMUL_N}",
    ]
    for name, text in artifacts.items():
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} {len(text)} chars")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
