"""L1 Pallas kernel: Mandelbrot escape iteration over one tile of points.

The paper's Mandelbrot hot-spot is the per-pixel escape loop inside the QT
RenderThread. Here it is re-thought for a TPU-style vector unit (see
DESIGN.md §Hardware-Adaptation): one `(TILE,)` lane vector of complex
coordinates per kernel invocation, the scalar per-pixel early-exit replaced
by a *vector* early-exit (`while_loop` runs until every lane escaped or the
iteration budget is exhausted), state held in VMEM-resident registers.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic custom
call that the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO, which is what ``aot.py`` ships to the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width of one tile. 1024 f32 lanes × 5 live vectors ≈ 20 KB of VMEM —
# far under budget; raised from 256 in the §Perf pass (EXPERIMENTS.md) to
# amortize the per-execute PJRT dispatch cost 4× on the rust hot path.
TILE = 256


def _mandel_kernel(cx_ref, cy_ref, max_iter_ref, o_ref):
    """Pallas kernel body: escape-iteration counts for one tile.

    Semantics match the scalar reference exactly: ``count`` is the number
    of z-updates applied before ``|z|^2 > 4`` was observed (checked
    *before* each update), saturating at ``max_iter`` for interior points.
    """
    cx = cx_ref[...]
    cy = cy_ref[...]
    max_iter = max_iter_ref[0]

    def cond(state):
        n, _zr, _zi, _count, active = state
        return jnp.logical_and(n < max_iter, jnp.any(active))

    def body(state):
        n, zr, zi, count, active = state
        zr2 = zr * zr
        zi2 = zi * zi
        # Lanes whose |z|^2 exceeds 4 *now* freeze their count.
        still_in = (zr2 + zi2) <= 4.0
        active = jnp.logical_and(active, still_in)
        # Masked z-update: frozen lanes keep their last z (their count no
        # longer changes, so the value is irrelevant — masking avoids
        # inf/nan propagation).
        new_zi = jnp.where(active, 2.0 * zr * zi + cy, zi)
        new_zr = jnp.where(active, zr2 - zi2 + cx, zr)
        count = count + jnp.where(active, 1, 0).astype(jnp.int32)
        return n + 1, new_zr, new_zi, count, active

    zeros = jnp.zeros_like(cx)
    init = (
        jnp.int32(0),
        zeros,
        zeros,
        jnp.zeros(cx.shape, jnp.int32),
        jnp.ones(cx.shape, jnp.bool_),
    )
    _, _, _, count, _ = jax.lax.while_loop(cond, body, init)
    o_ref[...] = count


@functools.partial(jax.jit, static_argnames=())
def mandel_tile(cx, cy, max_iter):
    """Escape counts for a tile.

    Args:
      cx, cy: f32[TILE] coordinates.
      max_iter: i32[1] iteration budget (runtime value, not baked into
        the artifact — the progressive passes reuse one executable).

    Returns:
      i32[TILE] iteration counts.
    """
    return pl.pallas_call(
        _mandel_kernel,
        out_shape=jax.ShapeDtypeStruct(cx.shape, jnp.int32),
        interpret=True,
    )(cx, cy, max_iter)
