"""L1 Pallas kernel: blocked matrix multiplication (the Fig. 3 running
example's hot-spot).

Grid over (i, j) output blocks; each kernel instance contracts a
(BM, N) row band of A with an (N, BN) column band of B — an MXU-shaped
``jnp.dot`` per block. BlockSpec expresses the HBM→VMEM schedule that the
C++ code expressed with its loop nest. ``interpret=True`` as everywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Matrix edge baked into the AOT artifact (must match
# rust/src/runtime::MATMUL_N).
N = 128
# Output block edge: 64×64 f32 blocks keep each instance's VMEM footprint
# at (64·128 + 128·64 + 64·64)·4 B ≈ 81 KB.
BLOCK = 64


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def matmul(a, b):
    """C = A @ B for f32[N, N] operands."""
    grid = (N // BLOCK, N // BLOCK)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK, N), lambda i, j: (i, 0)),
            pl.BlockSpec((N, BLOCK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK, BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, N), jnp.float32),
        interpret=True,
    )(a, b)
