"""Pure-jnp correctness oracles for the Pallas kernels.

These are the specifications the kernels (and, transitively, the Rust
PJRT path and the scalar Rust renderer) are tested against.
"""

import jax
import jax.numpy as jnp


def mandel_ref(cx, cy, max_iter):
    """Vectorized reference escape counts.

    Same contract as ``kernels.mandelbrot.mandel_tile`` and the Rust
    ``escape_iters``: count z-updates applied before |z|^2 > 4 (tested
    before each update), saturating at ``max_iter``.
    """
    cx = jnp.asarray(cx, jnp.float32)
    cy = jnp.asarray(cy, jnp.float32)
    max_iter = jnp.asarray(max_iter, jnp.int32).reshape(())

    def cond(state):
        n, _zr, _zi, _count, active = state
        return jnp.logical_and(n < max_iter, jnp.any(active))

    def body(state):
        n, zr, zi, count, active = state
        zr2 = zr * zr
        zi2 = zi * zi
        active = jnp.logical_and(active, (zr2 + zi2) <= 4.0)
        zi = jnp.where(active, 2.0 * zr * zi + cy, zi)
        zr = jnp.where(active, zr2 - zi2 + cx, zr)
        count = count + jnp.where(active, 1, 0).astype(jnp.int32)
        return n + 1, zr, zi, count, active

    zeros = jnp.zeros_like(cx)
    init = (
        jnp.int32(0),
        zeros,
        zeros,
        jnp.zeros(cx.shape, jnp.int32),
        jnp.ones(cx.shape, jnp.bool_),
    )
    _, _, _, count, _ = jax.lax.while_loop(cond, body, init)
    return count


def mandel_scalar_ref(cx: float, cy: float, max_iter: int) -> int:
    """Plain-python scalar oracle (mirrors Rust ``escape_iters``)."""
    zr = zi = 0.0
    i = 0
    while i < max_iter:
        zr2 = zr * zr
        zi2 = zi * zi
        if zr2 + zi2 > 4.0:
            break
        zi = 2.0 * zr * zi + cy
        zr = zr2 - zi2 + cx
        i += 1
    return i


def matmul_ref(a, b):
    """f32 matmul oracle."""
    return jnp.dot(
        jnp.asarray(a, jnp.float32),
        jnp.asarray(b, jnp.float32),
        preferred_element_type=jnp.float32,
    )
