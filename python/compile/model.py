"""L2: the jitted entry points AOT-lowered into the Rust-loadable
artifacts. Fixed shapes (AOT contract with rust/src/runtime/mod.rs):

* ``mandel_tile``: (f32[TILE], f32[TILE], i32[1]) -> i32[TILE]
* ``matmul``:     (f32[N, N], f32[N, N])          -> f32[N, N]

Both call the L1 Pallas kernels so the kernels lower into the same HLO
module; nothing here runs at request time.
"""

import jax
import jax.numpy as jnp

from compile.kernels import mandelbrot as mandel_kernel
from compile.kernels import matmul as matmul_kernel

TILE = mandel_kernel.TILE
MATMUL_N = matmul_kernel.N


def mandel_tile(cx, cy, max_iter):
    """Escape counts for one tile (see kernels.mandelbrot)."""
    return mandel_kernel.mandel_tile(cx, cy, max_iter)


def matmul(a, b):
    """C = A @ B (see kernels.matmul)."""
    return matmul_kernel.matmul(a, b)


def mandel_example_args():
    """ShapeDtypeStructs used to lower ``mandel_tile``."""
    return (
        jax.ShapeDtypeStruct((TILE,), jnp.float32),
        jax.ShapeDtypeStruct((TILE,), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
    )


def matmul_example_args():
    """ShapeDtypeStructs used to lower ``matmul``."""
    return (
        jax.ShapeDtypeStruct((MATMUL_N, MATMUL_N), jnp.float32),
        jax.ShapeDtypeStruct((MATMUL_N, MATMUL_N), jnp.float32),
    )
